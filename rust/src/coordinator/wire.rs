//! Wire protocol for the serving front-end: length-prefixed frames with
//! a versioned JSON body (docs/serving.md carries the byte-level spec).
//!
//! A frame is a 4-byte little-endian `u32` length followed by that many
//! bytes of UTF-8 JSON. Both directions use the same framing; a
//! connection is a sequence of request/response pairs. Requests carry
//! `"v": 1` ([`PROTOCOL_VERSION`]) and a `"type"` discriminator;
//! responses echo the request `id` and carry `"status"`: `"ok"`,
//! `"shed"` (admission control refused the request — retry later), or
//! `"error"`.
//!
//! Logits travel as `f32::to_bits` integers (`logits_bits`): every
//! `u32` is exactly representable as a JSON `f64` number, so the
//! bitwise-conformance contract (`tests/serving_wire.rs`) survives the
//! text encoding — decimal-formatted floats would not round-trip.
//!
//! This module owns the codec only; the server loop lives in
//! [`super::net`], the client side in [`crate::loadgen`].

use std::collections::BTreeMap;
use std::io::{self, Read, Write};

use anyhow::{bail, Context, Result};

use crate::quant::Precision;
use crate::sampling::Strategy;
use crate::util::{parse_json, JsonValue};

use super::request::RouteKey;

/// Protocol version stamped into every request (`"v"`). The server
/// rejects frames from a different major version with an error
/// response rather than guessing at field semantics.
pub const PROTOCOL_VERSION: u64 = 1;

/// Frame-length cap: a peer announcing more than this is refused
/// before any allocation (oversized lengths are how a garbage or
/// hostile byte stream would otherwise turn into an OOM).
pub const MAX_FRAME: usize = 16 << 20;

/// Read one length-prefixed frame. `Ok(None)` is a clean EOF — the
/// peer closed between frames; an EOF mid-frame or a length beyond
/// `max_frame` is an error (the stream can no longer be trusted).
pub fn read_frame(r: &mut impl Read, max_frame: usize) -> io::Result<Option<Vec<u8>>> {
    let mut first = [0u8; 1];
    loop {
        match r.read(&mut first) {
            Ok(0) => return Ok(None),
            Ok(_) => break,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    let mut rest = [0u8; 3];
    r.read_exact(&mut rest)?;
    let len = u32::from_le_bytes([first[0], rest[0], rest[1], rest[2]]) as usize;
    if len > max_frame {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("frame length {len} exceeds the {max_frame}-byte cap"),
        ));
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body)?;
    Ok(Some(body))
}

/// Write one length-prefixed frame and flush it.
pub fn write_frame(w: &mut impl Write, body: &[u8]) -> io::Result<()> {
    let len = u32::try_from(body.len()).map_err(|_| {
        io::Error::new(io::ErrorKind::InvalidInput, "frame body exceeds u32 length")
    })?;
    w.write_all(&len.to_le_bytes())?;
    w.write_all(body)?;
    w.flush()
}

/// One frame each way: encode `req`, read and parse the reply. The
/// client side of the protocol — loadgen workers and the conformance
/// tests drive servers through this.
pub fn roundtrip<S: Read + Write>(stream: &mut S, req: &WireRequest) -> Result<JsonValue> {
    write_frame(stream, req.to_json().to_string().as_bytes())
        .context("writing request frame")?;
    let body = read_frame(stream, MAX_FRAME)
        .context("reading response frame")?
        .context("server closed the connection mid-request")?;
    parse_json(std::str::from_utf8(&body).context("response frame is not UTF-8")?)
}

/// A decoded wire request.
#[derive(Clone, Debug, PartialEq)]
pub enum WireRequest {
    /// Classify `nodes` under a route; answered with per-node argmax
    /// predictions through the batched serving path.
    Infer { id: u64, route: RouteKey, nodes: Vec<usize> },
    /// Execute a route and return the raw logits as `f32::to_bits`
    /// integers — the bitwise-conformance entry.
    Logits { id: u64, route: RouteKey },
    /// Apply a live edge delta (`ops` are `graph::GraphDelta` text
    /// lines: `+ row col w` / `- row col` / `= row col w`).
    Mutate { id: u64, dataset: String, ops: Vec<String> },
    /// Shard-serving data plane: classify `nodes` (all owned by the
    /// addressed worker's row ranges) and report the epoch the served
    /// plan bound. Unlike `Infer` this skips the batcher — the router
    /// already batched across clients; a second coalescing stage would
    /// only add latency.
    ShardInfer { id: u64, route: RouteKey, nodes: Vec<usize> },
    /// Shard-serving data plane: execute a route and return the
    /// `[row_start, row_end)` slice of the logits matrix as
    /// `logits_bits`, plus the bound epoch. The router scatter/gathers
    /// these slices into the row-concatenation merge; only owned rows
    /// cross the wire.
    ShardLogits { id: u64, route: RouteKey, row_start: usize, row_end: usize },
    /// Replication log entry: apply `ops` expected to produce `epoch`.
    /// A worker already at (or past) `epoch` acks without re-applying
    /// (idempotent replay); a worker more than one epoch behind
    /// reports an epoch gap so the router replays earlier entries
    /// first. Control plane — never shed.
    ApplyDelta { id: u64, dataset: String, ops: Vec<String>, epoch: u64 },
    /// Ops surface: server identity, datasets, admission state.
    Status { id: u64 },
    /// Ops surface: full metrics snapshot.
    Metrics { id: u64 },
    /// Ops surface: per-route execution counts + latency quantiles.
    Routes { id: u64 },
}

fn obj(entries: Vec<(&str, JsonValue)>) -> JsonValue {
    JsonValue::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn num(x: u64) -> JsonValue {
    JsonValue::Num(x as f64)
}

/// Encode a route as its wire object (`width` is `null` for exact).
pub fn route_to_json(key: &RouteKey) -> JsonValue {
    obj(vec![
        ("model", JsonValue::Str(key.model.clone())),
        ("dataset", JsonValue::Str(key.dataset.clone())),
        (
            "width",
            key.width.map(|w| num(w as u64)).unwrap_or(JsonValue::Null),
        ),
        ("strategy", JsonValue::Str(key.strategy.name().to_string())),
        ("precision", JsonValue::Str(key.precision.name().to_string())),
    ])
}

/// Decode a route from the fields of a request object. `model` is
/// optional and defaults to `"gcn"` — v1 clients written before the
/// model zoo never sent one, and they keep meaning the GCN route.
pub fn route_from_json(v: &JsonValue) -> Result<RouteKey> {
    let model = match v.get("model") {
        Ok(JsonValue::Null) | Err(_) => "gcn".to_string(),
        Ok(m) => m.as_str().context("route: model must be a string")?.to_string(),
    };
    let dataset = v.get("dataset").context("route: missing dataset")?.as_str()?.to_string();
    let width = match v.get("width") {
        Ok(JsonValue::Null) | Err(_) => None,
        Ok(w) => Some(w.as_usize().context("route: width must be an integer")?),
    };
    let strategy_name = v.get("strategy").context("route: missing strategy")?.as_str()?;
    let strategy = Strategy::from_name(strategy_name)
        .with_context(|| format!("route: unknown strategy {strategy_name:?}"))?;
    let precision_name = v.get("precision").context("route: missing precision")?.as_str()?;
    let precision = Precision::from_name(precision_name)
        .with_context(|| format!("route: unknown precision {precision_name:?}"))?;
    Ok(RouteKey { model, dataset, width, strategy, precision })
}

impl WireRequest {
    /// Request id (echoed in the response).
    pub fn id(&self) -> u64 {
        match self {
            WireRequest::Infer { id, .. }
            | WireRequest::Logits { id, .. }
            | WireRequest::Mutate { id, .. }
            | WireRequest::ShardInfer { id, .. }
            | WireRequest::ShardLogits { id, .. }
            | WireRequest::ApplyDelta { id, .. }
            | WireRequest::Status { id }
            | WireRequest::Metrics { id }
            | WireRequest::Routes { id } => *id,
        }
    }

    pub fn to_json(&self) -> JsonValue {
        let mut map = BTreeMap::new();
        map.insert("v".to_string(), num(PROTOCOL_VERSION));
        map.insert("id".to_string(), num(self.id()));
        let kind = match self {
            WireRequest::Infer { route, nodes, .. } => {
                if let JsonValue::Obj(route_map) = route_to_json(route) {
                    map.extend(route_map);
                }
                map.insert(
                    "nodes".to_string(),
                    JsonValue::Arr(nodes.iter().map(|&n| num(n as u64)).collect()),
                );
                "infer"
            }
            WireRequest::Logits { route, .. } => {
                if let JsonValue::Obj(route_map) = route_to_json(route) {
                    map.extend(route_map);
                }
                "logits"
            }
            WireRequest::Mutate { dataset, ops, .. } => {
                map.insert("dataset".to_string(), JsonValue::Str(dataset.clone()));
                map.insert(
                    "ops".to_string(),
                    JsonValue::Arr(ops.iter().map(|o| JsonValue::Str(o.clone())).collect()),
                );
                "mutate"
            }
            WireRequest::ShardInfer { route, nodes, .. } => {
                if let JsonValue::Obj(route_map) = route_to_json(route) {
                    map.extend(route_map);
                }
                map.insert(
                    "nodes".to_string(),
                    JsonValue::Arr(nodes.iter().map(|&n| num(n as u64)).collect()),
                );
                "shard_infer"
            }
            WireRequest::ShardLogits { route, row_start, row_end, .. } => {
                if let JsonValue::Obj(route_map) = route_to_json(route) {
                    map.extend(route_map);
                }
                map.insert("row_start".to_string(), num(*row_start as u64));
                map.insert("row_end".to_string(), num(*row_end as u64));
                "shard_logits"
            }
            WireRequest::ApplyDelta { dataset, ops, epoch, .. } => {
                map.insert("dataset".to_string(), JsonValue::Str(dataset.clone()));
                map.insert(
                    "ops".to_string(),
                    JsonValue::Arr(ops.iter().map(|o| JsonValue::Str(o.clone())).collect()),
                );
                map.insert("epoch".to_string(), num(*epoch));
                "apply_delta"
            }
            WireRequest::Status { .. } => "status",
            WireRequest::Metrics { .. } => "metrics",
            WireRequest::Routes { .. } => "routes",
        };
        map.insert("type".to_string(), JsonValue::Str(kind.to_string()));
        JsonValue::Obj(map)
    }

    pub fn from_json(v: &JsonValue) -> Result<WireRequest> {
        let version = v.get("v").context("request: missing protocol version \"v\"")?.as_f64()?;
        if version as u64 != PROTOCOL_VERSION {
            bail!(
                "request: protocol version {version} unsupported \
                 (this server speaks {PROTOCOL_VERSION})"
            );
        }
        let id = request_id(v);
        let kind = v.get("type").context("request: missing type")?.as_str()?;
        match kind {
            "infer" => {
                let route = route_from_json(v)?;
                let nodes = v
                    .get("nodes")
                    .context("infer: missing nodes")?
                    .as_arr()?
                    .iter()
                    .map(|n| n.as_usize())
                    .collect::<Result<Vec<_>>>()
                    .context("infer: nodes must be integers")?;
                Ok(WireRequest::Infer { id, route, nodes })
            }
            "logits" => Ok(WireRequest::Logits { id, route: route_from_json(v)? }),
            "mutate" => {
                let dataset =
                    v.get("dataset").context("mutate: missing dataset")?.as_str()?.to_string();
                let ops = v
                    .get("ops")
                    .context("mutate: missing ops")?
                    .as_arr()?
                    .iter()
                    .map(|o| o.as_str().map(str::to_string))
                    .collect::<Result<Vec<_>>>()
                    .context("mutate: ops must be strings")?;
                Ok(WireRequest::Mutate { id, dataset, ops })
            }
            "shard_infer" => {
                let route = route_from_json(v)?;
                let nodes = v
                    .get("nodes")
                    .context("shard_infer: missing nodes")?
                    .as_arr()?
                    .iter()
                    .map(|n| n.as_usize())
                    .collect::<Result<Vec<_>>>()
                    .context("shard_infer: nodes must be integers")?;
                Ok(WireRequest::ShardInfer { id, route, nodes })
            }
            "shard_logits" => {
                let route = route_from_json(v)?;
                let row_start = v
                    .get("row_start")
                    .context("shard_logits: missing row_start")?
                    .as_usize()
                    .context("shard_logits: row_start must be an integer")?;
                let row_end = v
                    .get("row_end")
                    .context("shard_logits: missing row_end")?
                    .as_usize()
                    .context("shard_logits: row_end must be an integer")?;
                Ok(WireRequest::ShardLogits { id, route, row_start, row_end })
            }
            "apply_delta" => {
                let dataset = v
                    .get("dataset")
                    .context("apply_delta: missing dataset")?
                    .as_str()?
                    .to_string();
                let ops = v
                    .get("ops")
                    .context("apply_delta: missing ops")?
                    .as_arr()?
                    .iter()
                    .map(|o| o.as_str().map(str::to_string))
                    .collect::<Result<Vec<_>>>()
                    .context("apply_delta: ops must be strings")?;
                let epoch = v
                    .get("epoch")
                    .context("apply_delta: missing epoch")?
                    .as_f64()
                    .context("apply_delta: epoch must be a number")? as u64;
                Ok(WireRequest::ApplyDelta { id, dataset, ops, epoch })
            }
            "status" => Ok(WireRequest::Status { id }),
            "metrics" => Ok(WireRequest::Metrics { id }),
            "routes" => Ok(WireRequest::Routes { id }),
            other => bail!("request: unknown type {other:?}"),
        }
    }
}

/// Request/response id, 0 when absent or malformed (error responses to
/// unparseable frames still echo something addressable).
pub fn request_id(v: &JsonValue) -> u64 {
    v.get("id").ok().and_then(|n| n.as_f64().ok()).map(|f| f as u64).unwrap_or(0)
}

/// Response `status` field, `""` when absent.
pub fn response_status(v: &JsonValue) -> &str {
    v.get("status").ok().and_then(|s| s.as_str().ok()).unwrap_or("")
}

/// Start a response object: version, echoed id, status.
pub fn response_base(id: u64, status: &str) -> BTreeMap<String, JsonValue> {
    let mut map = BTreeMap::new();
    map.insert("v".to_string(), num(PROTOCOL_VERSION));
    map.insert("id".to_string(), num(id));
    map.insert("status".to_string(), JsonValue::Str(status.to_string()));
    map
}

/// An `"ok"` response carrying `fields`.
pub fn ok_response(id: u64, fields: Vec<(&str, JsonValue)>) -> JsonValue {
    let mut map = response_base(id, "ok");
    for (k, v) in fields {
        map.insert(k.to_string(), v);
    }
    JsonValue::Obj(map)
}

/// The load-shedding refusal: a distinct `"shed"` status (not an
/// error — the request was well-formed, the server is over its
/// high-water mark) plus the reason. Never a silent drop.
pub fn shed_response(id: u64, reason: &str) -> JsonValue {
    let mut map = response_base(id, "shed");
    map.insert("reason".to_string(), JsonValue::Str(reason.to_string()));
    JsonValue::Obj(map)
}

/// An `"error"` response with a message.
pub fn error_response(id: u64, msg: &str) -> JsonValue {
    let mut map = response_base(id, "error");
    map.insert("error".to_string(), JsonValue::Str(msg.to_string()));
    JsonValue::Obj(map)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn route() -> RouteKey {
        RouteKey {
            model: "gcn".into(),
            dataset: "evalpow".into(),
            width: Some(8),
            strategy: Strategy::Aes,
            precision: Precision::U8Device,
        }
    }

    #[test]
    fn frame_round_trip() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        write_frame(&mut buf, b"").unwrap();
        let mut cur = Cursor::new(buf);
        assert_eq!(read_frame(&mut cur, MAX_FRAME).unwrap().unwrap(), b"hello");
        assert_eq!(read_frame(&mut cur, MAX_FRAME).unwrap().unwrap(), b"");
        // Clean EOF between frames.
        assert!(read_frame(&mut cur, MAX_FRAME).unwrap().is_none());
    }

    #[test]
    fn oversized_frame_is_refused_before_allocation() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&(MAX_FRAME as u32 + 1).to_le_bytes());
        let err = read_frame(&mut Cursor::new(buf), MAX_FRAME).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
    }

    #[test]
    fn truncated_frame_is_an_error_not_eof() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&10u32.to_le_bytes());
        buf.extend_from_slice(b"abc"); // 3 of 10 bytes, then EOF
        assert!(read_frame(&mut Cursor::new(buf), MAX_FRAME).is_err());
    }

    #[test]
    fn requests_round_trip_through_json() {
        let reqs = [
            WireRequest::Infer { id: 7, route: route(), nodes: vec![0, 3, 159] },
            WireRequest::Logits { id: 8, route: RouteKey { width: None, ..route() } },
            WireRequest::Mutate {
                id: 9,
                dataset: "evalpow".into(),
                ops: vec!["+ 0 159 0.01".into(), "- 1 2".into()],
            },
            WireRequest::ShardInfer { id: 10, route: route(), nodes: vec![4, 5] },
            WireRequest::ShardLogits { id: 11, route: route(), row_start: 40, row_end: 80 },
            WireRequest::ApplyDelta {
                id: 12,
                dataset: "evalpow".into(),
                ops: vec!["= 0 1 0.25".into()],
                epoch: 3,
            },
            WireRequest::Status { id: 1 },
            WireRequest::Metrics { id: 2 },
            WireRequest::Routes { id: 3 },
        ];
        for req in reqs {
            let text = req.to_json().to_string();
            let back = WireRequest::from_json(&parse_json(&text).unwrap()).unwrap();
            assert_eq!(back, req, "round-trip mangled {text}");
        }
    }

    #[test]
    fn routes_without_a_model_default_to_gcn() {
        // The pre-model-zoo wire shape: no "model" field at all.
        let v1 = parse_json(
            r#"{"v":1,"type":"logits","id":5,"dataset":"evalpow",
                "width":null,"strategy":"aes","precision":"f32"}"#,
        )
        .unwrap();
        let WireRequest::Logits { route, .. } = WireRequest::from_json(&v1).unwrap() else {
            panic!("expected a logits request");
        };
        assert_eq!(route.model, "gcn");
        // An explicit model decodes as sent.
        let v2 = parse_json(
            r#"{"v":1,"type":"logits","id":6,"model":"gat","dataset":"evalpow",
                "width":8,"strategy":"aes","precision":"f32"}"#,
        )
        .unwrap();
        let WireRequest::Logits { route, .. } = WireRequest::from_json(&v2).unwrap() else {
            panic!("expected a logits request");
        };
        assert_eq!(route.model, "gat");
    }

    #[test]
    fn version_and_type_are_enforced() {
        let no_version = parse_json(r#"{"type":"status","id":1}"#).unwrap();
        assert!(WireRequest::from_json(&no_version).is_err());
        let bad_version = parse_json(r#"{"v":2,"type":"status","id":1}"#).unwrap();
        assert!(WireRequest::from_json(&bad_version).is_err());
        let bad_type = parse_json(r#"{"v":1,"type":"nope","id":1}"#).unwrap();
        assert!(WireRequest::from_json(&bad_type).is_err());
    }

    #[test]
    fn response_builders_carry_distinct_statuses() {
        let ok = ok_response(4, vec![("x", JsonValue::Num(1.0))]);
        let shed = shed_response(4, "high-water mark reached");
        let err = error_response(4, "boom");
        assert_eq!(response_status(&ok), "ok");
        assert_eq!(response_status(&shed), "shed");
        assert_eq!(response_status(&err), "error");
        for v in [&ok, &shed, &err] {
            assert_eq!(request_id(v), 4);
        }
        // The shed refusal is not an error and carries its reason.
        assert!(shed.get("error").is_err());
        assert!(shed.get("reason").unwrap().as_str().unwrap().contains("high-water"));
    }

    #[test]
    fn logits_bits_survive_json_exactly() {
        // The conformance contract: f32 bit patterns as JSON numbers.
        let vals = [0.1f32, -0.0, f32::MIN_POSITIVE, 123.456e-30];
        let arr = JsonValue::Arr(vals.iter().map(|v| num(v.to_bits() as u64)).collect());
        let text = arr.to_string();
        let back = parse_json(&text).unwrap();
        for (i, v) in back.as_arr().unwrap().iter().enumerate() {
            let bits = v.as_f64().unwrap() as u32;
            assert_eq!(f32::from_bits(bits).to_bits(), vals[i].to_bits());
        }
    }
}
