//! Dynamic batcher: groups queued requests by [`RouteKey`] and flushes a
//! group when it reaches `max_batch` or its oldest member has waited
//! `max_delay` — the standard serving trade-off (vLLM/Orca-style), applied
//! to full-graph GNN inference where a batch of N same-route requests
//! costs exactly one forward pass. Multi-group flushes (deadline sweeps
//! and the shutdown drain) emit oldest-first, so flush order — and the
//! latency accounting built on it — is deterministic rather than
//! `HashMap`-iteration-order dependent.

use std::collections::HashMap;
use std::sync::mpsc;
use std::time::{Duration, Instant};

use super::request::{InferRequest, RouteKey};

/// Batching policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// Flush a group at this many requests.
    pub max_batch: usize,
    /// Flush a group when its oldest request has waited this long.
    pub max_delay: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self { max_batch: 64, max_delay: Duration::from_millis(2) }
    }
}

/// A flushed group destined for one forward pass.
#[derive(Debug)]
pub struct Batch {
    pub key: RouteKey,
    pub requests: Vec<InferRequest>,
}

struct Group {
    requests: Vec<InferRequest>,
    oldest: Instant,
}

/// The batcher loop with a channel sink: drains `rx`, emits [`Batch`]es
/// to `tx`. Returns when `rx` disconnects, flushing everything queued.
pub fn run_batcher(cfg: BatcherConfig, rx: mpsc::Receiver<InferRequest>, tx: mpsc::Sender<Batch>) {
    run_batcher_with(cfg, rx, move |batch| tx.send(batch).is_ok())
}

/// The batcher loop with an arbitrary sink — the coordinator hands
/// batches straight to the worker pool (no relay channel, no relay
/// thread). The sink returns `false` to stop the loop (sink closed).
pub fn run_batcher_with(
    cfg: BatcherConfig,
    rx: mpsc::Receiver<InferRequest>,
    mut sink: impl FnMut(Batch) -> bool,
) {
    let mut groups: HashMap<RouteKey, Group> = HashMap::new();
    loop {
        // Wait bounded by the nearest group deadline.
        let timeout = groups
            .values()
            .map(|g| cfg.max_delay.saturating_sub(g.oldest.elapsed()))
            .min()
            .unwrap_or(Duration::from_millis(50));
        match rx.recv_timeout(timeout) {
            Ok(req) => {
                let key = req.key.clone();
                let group = groups.entry(key.clone()).or_insert_with(|| Group {
                    requests: Vec::new(),
                    oldest: req.enqueued,
                });
                group.oldest = group.oldest.min(req.enqueued);
                group.requests.push(req);
                if group.requests.len() >= cfg.max_batch {
                    let group = groups.remove(&key).unwrap();
                    if !sink(Batch { key, requests: group.requests }) {
                        return;
                    }
                }
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                // Shutdown drain. `HashMap::drain` yields groups in
                // arbitrary (seed-dependent) order, which made shutdown
                // latency accounting — and any test reading the flush
                // sequence — irreproducible. Flush oldest-first: the
                // deterministic order that also bounds the worst
                // queue-wait a drained request reports.
                let mut drained: Vec<(RouteKey, Group)> = groups.drain().collect();
                drained.sort_by_key(|(_, g)| g.oldest);
                for (key, group) in drained {
                    let _ = sink(Batch { key, requests: group.requests });
                }
                return;
            }
        }
        // Deadline flushes, oldest deadline first (same determinism
        // argument as the shutdown drain).
        let mut expired: Vec<(Instant, RouteKey)> = groups
            .iter()
            .filter(|(_, g)| g.oldest.elapsed() >= cfg.max_delay)
            .map(|(k, g)| (g.oldest, k.clone()))
            .collect();
        expired.sort_by_key(|&(oldest, _)| oldest);
        for (_, key) in expired {
            let group = groups.remove(&key).unwrap();
            if !sink(Batch { key, requests: group.requests }) {
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::Precision;
    use crate::sampling::Strategy;

    fn key(w: usize) -> RouteKey {
        RouteKey {
            model: "gcn".into(),
            dataset: "cora".into(),
            width: Some(w),
            strategy: Strategy::Aes,
            precision: Precision::F32,
        }
    }

    fn req(id: u64, k: RouteKey) -> (InferRequest, mpsc::Receiver<super::super::InferResponse>) {
        let (tx, rx) = mpsc::channel();
        (
            InferRequest { id, key: k, nodes: vec![0], enqueued: Instant::now(), reply: tx },
            rx,
        )
    }

    fn spawn_batcher(
        cfg: BatcherConfig,
    ) -> (mpsc::Sender<InferRequest>, mpsc::Receiver<Batch>, std::thread::JoinHandle<()>) {
        let (in_tx, in_rx) = mpsc::channel();
        let (out_tx, out_rx) = mpsc::channel();
        let h = std::thread::spawn(move || run_batcher(cfg, in_rx, out_tx));
        (in_tx, out_rx, h)
    }

    #[test]
    fn size_flush() {
        let (tx, rx, h) = spawn_batcher(BatcherConfig {
            max_batch: 3,
            max_delay: Duration::from_secs(10),
        });
        let mut replies = Vec::new();
        for i in 0..3 {
            let (r, reply) = req(i, key(16));
            replies.push(reply);
            tx.send(r).unwrap();
        }
        let batch = rx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(batch.requests.len(), 3);
        drop(tx);
        h.join().unwrap();
    }

    #[test]
    fn deadline_flush() {
        let (tx, rx, h) = spawn_batcher(BatcherConfig {
            max_batch: 1000,
            max_delay: Duration::from_millis(5),
        });
        let (r, _reply) = req(0, key(16));
        tx.send(r).unwrap();
        let batch = rx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(batch.requests.len(), 1);
        drop(tx);
        h.join().unwrap();
    }

    #[test]
    fn groups_by_key() {
        let (tx, rx, h) = spawn_batcher(BatcherConfig {
            max_batch: 2,
            max_delay: Duration::from_secs(10),
        });
        let mut replies = Vec::new();
        for (i, w) in [(0, 16), (1, 32), (2, 16), (3, 32)] {
            let (r, reply) = req(i, key(w));
            replies.push(reply);
            tx.send(r).unwrap();
        }
        let a = rx.recv_timeout(Duration::from_secs(1)).unwrap();
        let b = rx.recv_timeout(Duration::from_secs(1)).unwrap();
        for batch in [a, b] {
            assert_eq!(batch.requests.len(), 2);
            assert!(batch.requests.iter().all(|r| r.key == batch.key));
        }
        drop(tx);
        h.join().unwrap();
    }

    #[test]
    fn sink_variant_flushes_directly() {
        let (in_tx, in_rx) = mpsc::channel();
        let collected = std::sync::Arc::new(std::sync::Mutex::new(Vec::new()));
        let sink = collected.clone();
        let h = std::thread::spawn(move || {
            run_batcher_with(
                BatcherConfig { max_batch: 2, max_delay: Duration::from_secs(10) },
                in_rx,
                move |batch| {
                    sink.lock().unwrap().push(batch.requests.len());
                    true
                },
            )
        });
        let mut replies = Vec::new();
        for i in 0..4 {
            let (r, reply) = req(i, key(16));
            replies.push(reply);
            in_tx.send(r).unwrap();
        }
        drop(in_tx);
        h.join().unwrap();
        let sizes = collected.lock().unwrap().clone();
        assert_eq!(sizes.iter().sum::<usize>(), 4);
        assert!(sizes.iter().all(|&s| s <= 2));
    }

    /// Partial groups must drain oldest-first on disconnect — pinned
    /// order, not `HashMap` iteration order. Enqueue times are set
    /// explicitly so the expected order is unambiguous.
    #[test]
    fn shutdown_drain_is_oldest_first() {
        let (in_tx, in_rx) = mpsc::channel();
        let order = std::sync::Arc::new(std::sync::Mutex::new(Vec::new()));
        let sink = order.clone();
        let h = std::thread::spawn(move || {
            run_batcher_with(
                BatcherConfig { max_batch: 1000, max_delay: Duration::from_secs(10) },
                in_rx,
                move |batch| {
                    sink.lock().unwrap().push(batch.key.width.unwrap());
                    true
                },
            )
        });
        let now = Instant::now();
        let mut replies = Vec::new();
        // Send in shuffled width order; ages say 64 (oldest) → 16 → 32.
        for (w, age_ms) in [(32u64, 5u64), (64, 50), (16, 20)] {
            let (mut r, reply) = req(w, key(w as usize));
            r.enqueued = now - Duration::from_millis(age_ms);
            replies.push(reply);
            in_tx.send(r).unwrap();
        }
        drop(in_tx); // disconnect before any flush condition fires
        h.join().unwrap();
        assert_eq!(*order.lock().unwrap(), vec![64, 16, 32], "drain must be oldest-first");
    }

    /// Deadline sweeps flush every expired group oldest-first, and a
    /// group never waits past ~max_delay plus one recv bound: the wait
    /// timeout is derived from the nearest group deadline, so a queued
    /// group's flush latency is bounded even with no further traffic.
    #[test]
    fn deadline_flush_is_ordered_and_bounded() {
        let (in_tx, in_rx) = mpsc::channel();
        let order = std::sync::Arc::new(std::sync::Mutex::new(Vec::new()));
        let sink = order.clone();
        let max_delay = Duration::from_millis(20);
        let h = std::thread::spawn(move || {
            run_batcher_with(
                BatcherConfig { max_batch: 1000, max_delay },
                in_rx,
                move |batch| {
                    sink.lock().unwrap().push((batch.key.width.unwrap(), Instant::now()));
                    true
                },
            )
        });
        let now = Instant::now();
        let mut replies = Vec::new();
        // Two groups born 10ms apart (backdated), same sweep window.
        for (w, age_ms) in [(32u64, 0u64), (16, 10)] {
            let (mut r, reply) = req(w, key(w as usize));
            r.enqueued = now - Duration::from_millis(age_ms);
            replies.push(reply);
            in_tx.send(r).unwrap();
        }
        // No more traffic: both groups must still flush via deadlines.
        loop {
            let done = order.lock().unwrap().len() == 2;
            if done {
                break;
            }
            assert!(now.elapsed() < Duration::from_secs(5), "deadline flush never fired");
            std::thread::sleep(Duration::from_millis(1));
        }
        drop(in_tx);
        h.join().unwrap();
        let flushed = order.lock().unwrap().clone();
        assert_eq!(
            flushed.iter().map(|&(w, _)| w).collect::<Vec<_>>(),
            vec![16, 32],
            "expired groups must flush oldest-first"
        );
        // The deadline bound: every group flushed within max_delay of
        // its (backdated) birth, plus generous scheduling slack — the
        // bound distinguishes "flushed by its deadline" from "sat until
        // the 10s-scale fallback", not exact latency, so it stays far
        // above CI scheduler noise.
        let slack = Duration::from_secs(2);
        for &(w, at) in &flushed {
            let born = now - Duration::from_millis(if w == 16 { 10 } else { 0 });
            assert!(
                at.duration_since(born) <= max_delay + slack,
                "group w{w} waited {:?} past its deadline",
                at.duration_since(born)
            );
        }
    }

    #[test]
    fn drains_on_shutdown() {
        let (tx, rx, h) = spawn_batcher(BatcherConfig {
            max_batch: 1000,
            max_delay: Duration::from_secs(10),
        });
        let (r, _reply) = req(7, key(64));
        tx.send(r).unwrap();
        drop(tx); // disconnect before any flush condition fires
        let batch = rx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(batch.requests[0].id, 7);
        h.join().unwrap();
    }
}
