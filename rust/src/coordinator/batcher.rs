//! Dynamic batcher: groups queued requests by [`RouteKey`] and flushes a
//! group when it reaches `max_batch` or its oldest member has waited
//! `max_delay` — the standard serving trade-off (vLLM/Orca-style), applied
//! to full-graph GNN inference where a batch of N same-route requests
//! costs exactly one forward pass.

use std::collections::HashMap;
use std::sync::mpsc;
use std::time::{Duration, Instant};

use super::request::{InferRequest, RouteKey};

/// Batching policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// Flush a group at this many requests.
    pub max_batch: usize,
    /// Flush a group when its oldest request has waited this long.
    pub max_delay: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self { max_batch: 64, max_delay: Duration::from_millis(2) }
    }
}

/// A flushed group destined for one forward pass.
#[derive(Debug)]
pub struct Batch {
    pub key: RouteKey,
    pub requests: Vec<InferRequest>,
}

struct Group {
    requests: Vec<InferRequest>,
    oldest: Instant,
}

/// The batcher loop with a channel sink: drains `rx`, emits [`Batch`]es
/// to `tx`. Returns when `rx` disconnects, flushing everything queued.
pub fn run_batcher(cfg: BatcherConfig, rx: mpsc::Receiver<InferRequest>, tx: mpsc::Sender<Batch>) {
    run_batcher_with(cfg, rx, move |batch| tx.send(batch).is_ok())
}

/// The batcher loop with an arbitrary sink — the coordinator hands
/// batches straight to the worker pool (no relay channel, no relay
/// thread). The sink returns `false` to stop the loop (sink closed).
pub fn run_batcher_with(
    cfg: BatcherConfig,
    rx: mpsc::Receiver<InferRequest>,
    mut sink: impl FnMut(Batch) -> bool,
) {
    let mut groups: HashMap<RouteKey, Group> = HashMap::new();
    loop {
        // Wait bounded by the nearest group deadline.
        let timeout = groups
            .values()
            .map(|g| cfg.max_delay.saturating_sub(g.oldest.elapsed()))
            .min()
            .unwrap_or(Duration::from_millis(50));
        match rx.recv_timeout(timeout) {
            Ok(req) => {
                let key = req.key.clone();
                let group = groups.entry(key.clone()).or_insert_with(|| Group {
                    requests: Vec::new(),
                    oldest: req.enqueued,
                });
                group.oldest = group.oldest.min(req.enqueued);
                group.requests.push(req);
                if group.requests.len() >= cfg.max_batch {
                    let group = groups.remove(&key).unwrap();
                    if !sink(Batch { key, requests: group.requests }) {
                        return;
                    }
                }
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                for (key, group) in groups.drain() {
                    let _ = sink(Batch { key, requests: group.requests });
                }
                return;
            }
        }
        // Deadline flushes.
        let expired: Vec<RouteKey> = groups
            .iter()
            .filter(|(_, g)| g.oldest.elapsed() >= cfg.max_delay)
            .map(|(k, _)| k.clone())
            .collect();
        for key in expired {
            let group = groups.remove(&key).unwrap();
            if !sink(Batch { key, requests: group.requests }) {
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::Precision;
    use crate::sampling::Strategy;

    fn key(w: usize) -> RouteKey {
        RouteKey {
            model: "gcn".into(),
            dataset: "cora".into(),
            width: Some(w),
            strategy: Strategy::Aes,
            precision: Precision::F32,
        }
    }

    fn req(id: u64, k: RouteKey) -> (InferRequest, mpsc::Receiver<super::super::InferResponse>) {
        let (tx, rx) = mpsc::channel();
        (
            InferRequest { id, key: k, nodes: vec![0], enqueued: Instant::now(), reply: tx },
            rx,
        )
    }

    fn spawn_batcher(
        cfg: BatcherConfig,
    ) -> (mpsc::Sender<InferRequest>, mpsc::Receiver<Batch>, std::thread::JoinHandle<()>) {
        let (in_tx, in_rx) = mpsc::channel();
        let (out_tx, out_rx) = mpsc::channel();
        let h = std::thread::spawn(move || run_batcher(cfg, in_rx, out_tx));
        (in_tx, out_rx, h)
    }

    #[test]
    fn size_flush() {
        let (tx, rx, h) = spawn_batcher(BatcherConfig {
            max_batch: 3,
            max_delay: Duration::from_secs(10),
        });
        let mut replies = Vec::new();
        for i in 0..3 {
            let (r, reply) = req(i, key(16));
            replies.push(reply);
            tx.send(r).unwrap();
        }
        let batch = rx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(batch.requests.len(), 3);
        drop(tx);
        h.join().unwrap();
    }

    #[test]
    fn deadline_flush() {
        let (tx, rx, h) = spawn_batcher(BatcherConfig {
            max_batch: 1000,
            max_delay: Duration::from_millis(5),
        });
        let (r, _reply) = req(0, key(16));
        tx.send(r).unwrap();
        let batch = rx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(batch.requests.len(), 1);
        drop(tx);
        h.join().unwrap();
    }

    #[test]
    fn groups_by_key() {
        let (tx, rx, h) = spawn_batcher(BatcherConfig {
            max_batch: 2,
            max_delay: Duration::from_secs(10),
        });
        let mut replies = Vec::new();
        for (i, w) in [(0, 16), (1, 32), (2, 16), (3, 32)] {
            let (r, reply) = req(i, key(w));
            replies.push(reply);
            tx.send(r).unwrap();
        }
        let a = rx.recv_timeout(Duration::from_secs(1)).unwrap();
        let b = rx.recv_timeout(Duration::from_secs(1)).unwrap();
        for batch in [a, b] {
            assert_eq!(batch.requests.len(), 2);
            assert!(batch.requests.iter().all(|r| r.key == batch.key));
        }
        drop(tx);
        h.join().unwrap();
    }

    #[test]
    fn sink_variant_flushes_directly() {
        let (in_tx, in_rx) = mpsc::channel();
        let collected = std::sync::Arc::new(std::sync::Mutex::new(Vec::new()));
        let sink = collected.clone();
        let h = std::thread::spawn(move || {
            run_batcher_with(
                BatcherConfig { max_batch: 2, max_delay: Duration::from_secs(10) },
                in_rx,
                move |batch| {
                    sink.lock().unwrap().push(batch.requests.len());
                    true
                },
            )
        });
        let mut replies = Vec::new();
        for i in 0..4 {
            let (r, reply) = req(i, key(16));
            replies.push(reply);
            in_tx.send(r).unwrap();
        }
        drop(in_tx);
        h.join().unwrap();
        let sizes = collected.lock().unwrap().clone();
        assert_eq!(sizes.iter().sum::<usize>(), 4);
        assert!(sizes.iter().all(|&s| s <= 2));
    }

    #[test]
    fn drains_on_shutdown() {
        let (tx, rx, h) = spawn_batcher(BatcherConfig {
            max_batch: 1000,
            max_delay: Duration::from_secs(10),
        });
        let (r, _reply) = req(7, key(64));
        tx.send(r).unwrap();
        drop(tx); // disconnect before any flush condition fires
        let batch = rx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(batch.requests[0].id, 7);
        h.join().unwrap();
    }
}
