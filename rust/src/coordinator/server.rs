//! The coordinator itself: bounded intake queue → batcher thread → the
//! persistent exec-layer worker pool, with a per-route plan cache so warm
//! routes never touch the feature store.
//!
//! Execution topology (vs the seed): the batcher hands each flushed
//! [`Batch`] straight to [`crate::exec::Pool`] (per-worker queues + work
//! stealing) instead of pushing it through a `Mutex<Receiver>` that every
//! worker contended; workers are spawned once at startup and parked
//! between batches.
//!
//! Cold routes additionally go through the async prefetcher: `submit`
//! kicks the route's plan build (feature staging + sampling) onto a
//! private prefetch pool *before* the request even reaches the batcher,
//! so staging overlaps the batching delay window and whatever SpMM the
//! workers are already running; by the time a worker executes the batch,
//! [`crate::exec::Prefetcher::fetch`] usually finds the plan warm. The
//! prefetch pool is deliberately separate from the batch pool — a batch
//! worker blocks in `fetch`, and it must never be able to block on a
//! build queued behind itself.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::exec::{
    prepare_plan, ExecEnv, ExecPlan, PlanCache, PlanSpec, Pool, PrefetchStats, Prefetcher,
    ShardKey, ShardUnit,
};
use crate::graph::ShardSpec;
use crate::quant::{Features, Precision};
use crate::runtime::{accuracy, run_forward, Backend, Engine};
use crate::sampling::Strategy;
use crate::tensor::Tensor;
use crate::util::argmax_f32;

use super::batcher::{run_batcher_with, Batch, BatcherConfig};
use super::metrics::Metrics;
use super::request::{InferRequest, InferResponse, Prediction, RouteKey, SubmitError};
use super::store::ModelStore;

/// Coordinator construction knobs.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    pub batcher: BatcherConfig,
    /// Worker threads executing batches.
    pub workers: usize,
    /// Bounded intake queue length (backpressure beyond this).
    pub queue_depth: usize,
    /// Route plans kept warm (LRU beyond this many).
    pub plan_cache_capacity: usize,
    /// Threads staging cold route plans ahead of execution (0 disables
    /// prefetch; cold builds then run inline on the batch workers).
    pub prefetch_workers: usize,
    /// Row-shard host aggregation plans: partition each route's operand
    /// into working-set-budgeted shards with per-shard sampling and
    /// per-shard kernel dispatch (`--shards` / `--shard-budget`).
    /// `None` keeps single-working-set plans. Ignored by device
    /// backends, which aggregate in the compiled artifact.
    pub sharding: Option<ShardSpec>,
    /// Stage features through the zero-copy streaming path on
    /// host-aggregating backends (`FeatureStore::stage`: mmap row-block
    /// handles, lazy per-block dequant). `false` forces eager loads —
    /// the accuracy-conformance eval uses both settings to pin the
    /// streamed-vs-eager bitwise guarantee through the serving path.
    /// Ignored by device backends (always eager) and by fp32 routes
    /// (which never stream).
    pub streaming: bool,
    /// Prepared shard units kept warm across routes and precisions
    /// (LRU; units are pure graph structure, so one entry serves every
    /// route over the same operand).
    pub shard_cache_capacity: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            batcher: BatcherConfig::default(),
            workers: 2,
            queue_depth: 1024,
            plan_cache_capacity: 64,
            prefetch_workers: 1,
            sharding: None,
            streaming: true,
            shard_cache_capacity: 256,
        }
    }
}

/// What a route plan is keyed by. Narrower than [`RouteKey`]: the model
/// never changes the feature tensor, and on device backends (fused
/// in-kernel sampling) neither do width/strategy — so e.g. `gcn` and
/// `sage` routes over one dataset share a single cached feature load.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
struct PlanKey {
    dataset: String,
    precision: Precision,
    /// Host-aggregating backends key the sampled ELL plan too.
    width: Option<usize>,
    strategy: Option<Strategy>,
}

impl PlanKey {
    fn for_route(key: &RouteKey, host_aggregation: bool) -> PlanKey {
        if host_aggregation {
            PlanKey {
                dataset: key.dataset.clone(),
                precision: key.precision,
                width: key.width,
                // Strategy only matters when something is sampled — exact
                // host routes share one plan regardless of strategy.
                strategy: key.width.map(|_| key.strategy),
            }
        } else {
            PlanKey {
                dataset: key.dataset.clone(),
                precision: key.precision,
                width: None,
                strategy: None,
            }
        }
    }
}

/// Point-in-time shard-unit cache counters (see
/// [`Coordinator::shard_stats`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ShardCacheStats {
    /// Prepared shard units currently resident.
    pub resident: usize,
    /// Unit lookups served warm (no re-partition, no re-sampling).
    pub hits: u64,
    /// Unit lookups that had to build.
    pub misses: u64,
    /// Units dropped by LRU overflow.
    pub evictions: u64,
}

/// Everything a pool worker needs to execute a batch.
struct WorkerCtx {
    backend: Backend,
    store: Arc<ModelStore>,
    metrics: Arc<Metrics>,
    plans: Arc<PlanCache<PlanKey, ExecPlan>>,
    /// Stages cold plans on its own pool; `None` when disabled.
    prefetch: Option<Prefetcher<PlanKey, ExecPlan>>,
    /// Sharding policy for host aggregation plans (`None` = unsharded).
    sharding: Option<ShardSpec>,
    /// Whether host plans stage features through the streaming path.
    streaming: bool,
    /// Prepared shard units, shared across routes/precisions — a plan
    /// build (inline or prefetched) samples only the cold shards.
    shard_units: Arc<PlanCache<ShardKey, ShardUnit>>,
    env: ExecEnv,
}

/// Handle to a running coordinator. Dropping it (or calling
/// [`Coordinator::shutdown`]) drains the pipeline and joins all threads.
pub struct Coordinator {
    intake: Option<mpsc::SyncSender<InferRequest>>,
    ctx: Arc<WorkerCtx>,
    next_id: AtomicU64,
    batcher: Option<JoinHandle<()>>,
    pool: Option<Arc<Pool>>,
}

impl Coordinator {
    /// Start over the PJRT engine (production path). Alias for
    /// [`Coordinator::start_with`] with [`Backend::Pjrt`].
    pub fn start(
        engine: Arc<Engine>,
        store: Arc<ModelStore>,
        cfg: CoordinatorConfig,
    ) -> Coordinator {
        Coordinator::start_with(Backend::Pjrt(engine), store, cfg)
    }

    /// Start the batcher + persistent worker pool over any [`Backend`].
    pub fn start_with(
        backend: Backend,
        store: Arc<ModelStore>,
        cfg: CoordinatorConfig,
    ) -> Coordinator {
        let plans = Arc::new(PlanCache::new(cfg.plan_cache_capacity));
        let prefetch = (cfg.prefetch_workers > 0)
            .then(|| Prefetcher::new(plans.clone(), Arc::new(Pool::new(cfg.prefetch_workers))));
        let ctx = Arc::new(WorkerCtx {
            backend,
            store,
            metrics: Arc::new(Metrics::new()),
            plans,
            prefetch,
            sharding: cfg.sharding,
            streaming: cfg.streaming,
            shard_units: Arc::new(PlanCache::new(cfg.shard_cache_capacity)),
            env: ExecEnv::detect(),
        });
        let pool = Arc::new(Pool::new(cfg.workers.max(1)));
        let (intake_tx, intake_rx) = mpsc::sync_channel::<InferRequest>(cfg.queue_depth);

        let batcher = {
            let pool = pool.clone();
            let ctx = ctx.clone();
            let bcfg = cfg.batcher;
            std::thread::Builder::new()
                .name("coordinator-batcher".into())
                .spawn(move || {
                    run_batcher_with(bcfg, intake_rx, move |batch| {
                        let ctx = ctx.clone();
                        pool.spawn(move || run_batch(&ctx, batch));
                        true
                    })
                })
                .expect("spawning batcher thread")
        };

        Coordinator {
            intake: Some(intake_tx),
            ctx,
            next_id: AtomicU64::new(1),
            batcher: Some(batcher),
            pool: Some(pool),
        }
    }

    /// Submit a query; returns the request id and the reply receiver.
    /// Fails fast with [`SubmitError::Busy`] when the queue is full.
    pub fn submit(
        &self,
        key: RouteKey,
        nodes: Vec<usize>,
    ) -> Result<(u64, mpsc::Receiver<InferResponse>), SubmitError> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let intake = self.intake.as_ref().ok_or(SubmitError::Closed)?;
        // Claim the route's prefetch slot before `key` moves into the
        // request: warm / already-staging routes coalesce on a cache peek
        // (no clones, no closure); a cold route's claim makes any batch
        // worker racing ahead wait for the build instead of duplicating
        // it. The build itself is only scheduled once the request is
        // admitted — a backpressure rejection drops the ticket, releasing
        // the claim without any storage work.
        let staging = self.ctx.prefetch.as_ref().and_then(|p| {
            let plan_key = PlanKey::for_route(&key, self.ctx.backend.aggregates_on_host());
            p.begin(plan_key).map(|ticket| (ticket, key.clone()))
        });
        let (reply_tx, reply_rx) = mpsc::channel();
        let req = InferRequest { id, key, nodes, enqueued: Instant::now(), reply: reply_tx };
        self.ctx.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        match intake.try_send(req) {
            Ok(()) => {
                if let Some((ticket, key)) = staging {
                    // Staging overlaps the batching window and whatever
                    // SpMM the workers are already executing.
                    let ctx = self.ctx.clone();
                    ticket.commit(move || build_plan(&ctx, &key));
                }
                Ok((id, reply_rx))
            }
            Err(mpsc::TrySendError::Full(_)) => {
                self.ctx.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                Err(SubmitError::Busy)
            }
            Err(mpsc::TrySendError::Disconnected(_)) => Err(SubmitError::Closed),
        }
    }

    /// Blocking submit-and-wait convenience.
    pub fn infer(&self, key: RouteKey, nodes: Vec<usize>) -> Result<InferResponse> {
        let (_, rx) = self.submit(key, nodes).map_err(anyhow::Error::from)?;
        Ok(rx.recv()?)
    }

    /// Execute one route synchronously through the full serving data
    /// path — plan cache, prefetcher, sharded execution, backend — and
    /// return the raw logits tensor.
    ///
    /// This is the accuracy-conformance entry (`eval::run_eval`,
    /// `tests/accuracy.rs`): it resolves and executes the route exactly
    /// the way a batch worker does (the batched request path only adds
    /// grouping and per-node argmax on top), but hands back the logits
    /// so differential metrics can be computed against the exact oracle.
    /// Runs on the calling thread; plan-cache hit/miss and
    /// sharded-batch metrics are recorded as usual.
    pub fn route_logits(&self, key: &RouteKey) -> Result<Tensor> {
        let (logits, ..) = execute_route(&self.ctx, key)?;
        Ok(logits)
    }

    pub fn metrics(&self) -> &Metrics {
        &self.ctx.metrics
    }

    /// Worker threads in the batch pool (constant for the coordinator's
    /// lifetime — workers are never re-spawned per batch).
    pub fn pool_workers(&self) -> usize {
        self.pool.as_ref().map(|p| p.worker_count()).unwrap_or(0)
    }

    /// Cached route plans currently warm.
    pub fn plan_cache_len(&self) -> usize {
        self.ctx.plans.len()
    }

    /// Shard-unit cache counters (all zeros until a sharded route
    /// builds). Units are shared across routes and precisions, so
    /// `hits` counts shards a plan build did *not* have to re-sample.
    pub fn shard_stats(&self) -> ShardCacheStats {
        let units = &self.ctx.shard_units;
        ShardCacheStats {
            resident: units.len(),
            hits: units.hits(),
            misses: units.misses(),
            evictions: units.evictions(),
        }
    }

    /// Warm a route ahead of traffic: stage its plan (feature load +
    /// sampling + dispatch) on the prefetch pool without submitting a
    /// request. Returns `true` when a build was scheduled, `false` when
    /// the route was already warm/in-flight or prefetch is disabled.
    pub fn prefetch_route(&self, key: &RouteKey) -> bool {
        self.spawn_prefetch(key)
    }

    /// Prefetcher counters (all zeros when prefetch is disabled).
    pub fn prefetch_stats(&self) -> PrefetchStats {
        self.ctx.prefetch.as_ref().map(|p| p.stats()).unwrap_or_default()
    }

    /// Block until no prefetch build is queued or running (tests and
    /// warm-up scripts that want a deterministic cache state).
    pub fn wait_prefetch_idle(&self) {
        if let Some(p) = &self.ctx.prefetch {
            p.wait_idle();
        }
    }

    fn spawn_prefetch(&self, key: &RouteKey) -> bool {
        let Some(p) = &self.ctx.prefetch else { return false };
        let plan_key = PlanKey::for_route(key, self.ctx.backend.aggregates_on_host());
        let Some(ticket) = p.begin(plan_key) else { return false };
        let ctx = self.ctx.clone();
        let key = key.clone();
        ticket.commit(move || build_plan(&ctx, &key));
        true
    }

    /// Drop every cached plan and shard unit of the route's **dataset**
    /// (republished data / rotated features); the next batch on any of
    /// its routes reloads from storage. Invalidation is per-dataset, not
    /// per-route, because sibling routes (other precisions, widths,
    /// models) share the same underlying graph and feature file —
    /// dropping only one would leave the others serving stale data.
    /// Returns whether any plan was resident.
    pub fn invalidate_route(&self, key: &RouteKey) -> bool {
        self.ctx.shard_units.invalidate_matching(|k| k.tag == key.dataset);
        self.ctx.plans.invalidate_matching(|k| k.dataset == key.dataset) > 0
    }

    /// Drop every cached plan and shard unit.
    pub fn invalidate_all_routes(&self) {
        self.ctx.plans.clear();
        self.ctx.shard_units.clear();
    }

    /// Drain the pipeline and join all threads.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        // Disconnect intake → batcher flushes pending groups into the
        // pool and exits → pool drains its queues → workers join.
        self.intake.take();
        if let Some(batcher) = self.batcher.take() {
            let _ = batcher.join();
        }
        if let Some(pool) = self.pool.take() {
            pool.wait_idle();
            // The batcher's clone is gone (joined above), so this drop is
            // the last reference and joins the parked workers.
            drop(pool);
        }
        // Let any still-running prefetch build finish cleanly; its pool
        // joins when the ctx (and with it the prefetcher) drops.
        if let Some(p) = &self.ctx.prefetch {
            p.wait_idle();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

/// Execute one batch: resolve the route plan (cache hit = no disk), run
/// the backend once, answer every member request.
fn run_batch(ctx: &WorkerCtx, batch: Batch) {
    let size = batch.requests.len();
    let metrics = &ctx.metrics;
    metrics.batches.fetch_add(1, Ordering::Relaxed);
    metrics.record_route(&batch.key.label());
    for r in &batch.requests {
        metrics.queue_wait.record(r.enqueued.elapsed());
    }

    match execute_route(ctx, &batch.key) {
        Ok((logits, classes, load_time, exec_time, plan_hit)) => {
            metrics.load_time.record(load_time);
            metrics.exec_time.record(exec_time);
            if plan_hit {
                // Misses are counted where plans are actually built
                // (`build_plan`), which may be the prefetcher rather than
                // this worker; a hit here includes plans a prefetch
                // finished while the batch waited.
                metrics.plan_hits.fetch_add(1, Ordering::Relaxed);
            }
            let vals = match logits.as_f32() {
                Ok(v) => v,
                Err(e) => return fail_batch(metrics, batch, &e.to_string()),
            };
            for req in batch.requests {
                let predictions = req
                    .nodes
                    .iter()
                    .map(|&node| Prediction { node, class: argmax_row(vals, node, classes) })
                    .collect();
                let latency = req.enqueued.elapsed();
                metrics.latency.record(latency);
                metrics.completed.fetch_add(1, Ordering::Relaxed);
                let _ = req.reply.send(InferResponse {
                    id: req.id,
                    predictions,
                    latency,
                    batch_size: size,
                    error: None,
                });
            }
        }
        Err(e) => fail_batch(metrics, batch, &format!("{e:#}")),
    }
}

fn fail_batch(metrics: &Metrics, batch: Batch, msg: &str) {
    for req in batch.requests {
        metrics.failed.fetch_add(1, Ordering::Relaxed);
        let _ = req.reply.send(InferResponse {
            id: req.id,
            predictions: Vec::new(),
            latency: req.enqueued.elapsed(),
            batch_size: 0,
            error: Some(msg.to_string()),
        });
    }
}

/// Build one route's plan — the cold path, whether it runs inline on a
/// batch worker or ahead of time on the prefetch pool. Counts itself as
/// a plan miss (builds are the meaningful "miss" once staging can happen
/// off the critical path).
fn build_plan(ctx: &WorkerCtx, key: &RouteKey) -> Result<ExecPlan> {
    ctx.metrics.plan_misses.fetch_add(1, Ordering::Relaxed);
    let ds = ctx.store.dataset(&key.dataset)?;
    let fstore = ctx.store.feature_store(&key.dataset)?;
    let host_aggregation = ctx.backend.aggregates_on_host();
    // Sharding is a host-aggregation concern; device artifacts aggregate
    // in-kernel and keep the single-operand plan.
    let shard = if host_aggregation { ctx.sharding } else { None };
    let spec = PlanSpec {
        csr: &ds.csr_gcn,
        width: if host_aggregation { key.width } else { None },
        strategy: key.strategy,
        host_ell: host_aggregation,
        // Host aggregation consumes features row-block-wise, so the plan
        // can hold a zero-copy streamed handle; device artifacts need the
        // eagerly materialized tensor. The eval harness flips
        // `CoordinatorConfig::streaming` off to pin streamed-vs-eager
        // bitwise equality through this exact path.
        stream: host_aggregation && ctx.streaming,
        shard,
        // Units are keyed by dataset + width + strategy + row range, so a
        // build for one precision warms every sibling route's shards.
        shard_cache: shard.map(|_| (&*ctx.shard_units, key.dataset.as_str())),
    };
    prepare_plan(&fstore, key.precision, &spec, ds.feats, &ctx.env)
}

/// Forward pass for one route through its (possibly cached) plan.
/// Returns (logits, classes, load, exec, plan_hit).
///
/// Cold route: the plan build performs the instrumented feature staging —
/// the stage the paper's Table 3 measures. With prefetch enabled the
/// build usually ran (or is running) on the prefetch pool already; this
/// worker waits for it instead of duplicating the storage read. Warm
/// route: the plan comes from memory and `load` is zero, which is the
/// whole point of the cache.
fn execute_route(
    ctx: &WorkerCtx,
    key: &RouteKey,
) -> Result<(Tensor, usize, Duration, Duration, bool)> {
    let ds = ctx.store.dataset(&key.dataset)?;
    let weights = ctx.store.weights(&key.model, &key.dataset)?;

    let host_aggregation = ctx.backend.aggregates_on_host();
    let plan_key = PlanKey::for_route(key, host_aggregation);
    let (plan, hit) = match &ctx.prefetch {
        Some(p) => p.fetch(&plan_key, || build_plan(ctx, key))?,
        None => ctx.plans.get_or_try_insert(&plan_key, || build_plan(ctx, key))?,
    };
    if plan.sharded.is_some() {
        ctx.metrics.sharded_batches.fetch_add(1, Ordering::Relaxed);
    }

    let feat_tensor = match &plan.features {
        Features::Dense(t) => Some(t),
        Features::Quantized { q, .. } => Some(q),
        // The host backend streams row-blocks straight from the plan's
        // handle; there is no materialized tensor to pass.
        Features::Streamed(_) => None,
    };

    let fwd = key.to_forward();
    let result = ctx.backend.forward(&ds, &weights, &fwd, feat_tensor, Some(&*plan), &ctx.env)?;
    let load_time = if hit { Duration::ZERO } else { plan.load_stats.total() };
    Ok((result.logits, ds.classes, load_time, result.stats.total(), hit))
}

/// NaN-safe per-node argmax (deterministic: NaN loses, ties break low,
/// all-NaN rows yield class 0).
fn argmax_row(vals: &[f32], row: usize, classes: usize) -> i32 {
    argmax_f32(&vals[row * classes..(row + 1) * classes]) as i32
}

/// Convenience used by examples: run a route once outside the service and
/// report its test accuracy.
pub fn oneshot_accuracy(engine: &Engine, store: &ModelStore, key: &RouteKey) -> Result<f64> {
    let ds = store.dataset(&key.dataset)?;
    let weights = store.weights(&key.model, &key.dataset)?;
    let result = run_forward(engine, &ds, &weights, &key.to_forward(), None)?;
    accuracy(&ds, &result.logits)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_picks_max() {
        let vals = [0.1f32, 0.9, -1.0, 3.0, 2.0, 1.0];
        assert_eq!(argmax_row(&vals, 0, 3), 1);
        assert_eq!(argmax_row(&vals, 1, 3), 0);
    }

    #[test]
    fn argmax_survives_nan_logits() {
        // The seed panicked the worker thread here (partial_cmp unwrap).
        let vals = [f32::NAN, 0.5, 0.2, f32::NAN, f32::NAN, f32::NAN];
        assert_eq!(argmax_row(&vals, 0, 3), 1);
        // All-NaN row: deterministic class 0, not a panic.
        assert_eq!(argmax_row(&vals, 1, 3), 0);
    }

    #[test]
    fn plan_key_collapses_device_routes() {
        let mk = |width, strategy, precision| RouteKey {
            model: "gcn".into(),
            dataset: "cora".into(),
            width,
            strategy,
            precision,
        };
        // Device backends: one plan per (dataset, precision).
        let a = PlanKey::for_route(&mk(Some(16), Strategy::Aes, Precision::F32), false);
        let b = PlanKey::for_route(&mk(Some(64), Strategy::Sfs, Precision::F32), false);
        assert_eq!(a, b);
        let c = PlanKey::for_route(&mk(Some(16), Strategy::Aes, Precision::U8Device), false);
        assert_ne!(a, c);
        // Host backends: the sampled plan differs per width/strategy.
        let d = PlanKey::for_route(&mk(Some(16), Strategy::Aes, Precision::F32), true);
        let e = PlanKey::for_route(&mk(Some(64), Strategy::Aes, Precision::F32), true);
        assert_ne!(d, e);
        // ...but exact host routes ignore the (unused) strategy field.
        let f = PlanKey::for_route(&mk(None, Strategy::Aes, Precision::F32), true);
        let g = PlanKey::for_route(&mk(None, Strategy::Sfs, Precision::F32), true);
        assert_eq!(f, g);
    }
}
