//! The coordinator itself: bounded intake queue → batcher thread → worker
//! pool executing batches through the PJRT engine.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::Result;

use crate::runtime::{accuracy, run_forward, Engine};
use crate::tensor::Tensor;

use super::batcher::{run_batcher, Batch, BatcherConfig};
use super::metrics::Metrics;
use super::request::{InferRequest, InferResponse, Prediction, RouteKey, SubmitError};
use super::store::ModelStore;

/// Coordinator construction knobs.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    pub batcher: BatcherConfig,
    /// Worker threads executing batches.
    pub workers: usize,
    /// Bounded intake queue length (backpressure beyond this).
    pub queue_depth: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self { batcher: BatcherConfig::default(), workers: 2, queue_depth: 1024 }
    }
}

/// Handle to a running coordinator. Dropping it (or calling
/// [`Coordinator::shutdown`]) drains the pipeline and joins all threads.
pub struct Coordinator {
    intake: Option<mpsc::SyncSender<InferRequest>>,
    metrics: Arc<Metrics>,
    next_id: AtomicU64,
    threads: Vec<JoinHandle<()>>,
}

impl Coordinator {
    /// Start the batcher + worker pool over a shared engine and store.
    pub fn start(
        engine: Arc<Engine>,
        store: Arc<ModelStore>,
        cfg: CoordinatorConfig,
    ) -> Coordinator {
        let metrics = Arc::new(Metrics::new());
        let (intake_tx, intake_rx) = mpsc::sync_channel::<InferRequest>(cfg.queue_depth);
        let (batch_tx, batch_rx) = mpsc::channel::<Batch>();
        let batch_rx = Arc::new(Mutex::new(batch_rx));

        let mut threads = Vec::new();
        let bcfg = cfg.batcher;
        threads.push(std::thread::spawn(move || run_batcher(bcfg, intake_rx, batch_tx)));

        for _ in 0..cfg.workers.max(1) {
            let rx = batch_rx.clone();
            let engine = engine.clone();
            let store = store.clone();
            let metrics = metrics.clone();
            threads.push(std::thread::spawn(move || {
                loop {
                    let batch = {
                        let guard = rx.lock().unwrap();
                        guard.recv()
                    };
                    match batch {
                        Ok(b) => run_batch(&engine, &store, &metrics, b),
                        Err(_) => return,
                    }
                }
            }));
        }

        Coordinator {
            intake: Some(intake_tx),
            metrics,
            next_id: AtomicU64::new(1),
            threads,
        }
    }

    /// Submit a query; returns the request id and the reply receiver.
    /// Fails fast with [`SubmitError::Busy`] when the queue is full.
    pub fn submit(
        &self,
        key: RouteKey,
        nodes: Vec<usize>,
    ) -> Result<(u64, mpsc::Receiver<InferResponse>), SubmitError> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (reply_tx, reply_rx) = mpsc::channel();
        let req = InferRequest { id, key, nodes, enqueued: Instant::now(), reply: reply_tx };
        let intake = self.intake.as_ref().ok_or(SubmitError::Closed)?;
        self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        match intake.try_send(req) {
            Ok(()) => Ok((id, reply_rx)),
            Err(mpsc::TrySendError::Full(_)) => {
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                Err(SubmitError::Busy)
            }
            Err(mpsc::TrySendError::Disconnected(_)) => Err(SubmitError::Closed),
        }
    }

    /// Blocking submit-and-wait convenience.
    pub fn infer(&self, key: RouteKey, nodes: Vec<usize>) -> Result<InferResponse> {
        let (_, rx) = self.submit(key, nodes).map_err(anyhow::Error::from)?;
        Ok(rx.recv()?)
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Drain the pipeline and join all threads.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        self.intake.take(); // disconnect → batcher drains → workers exit
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

/// Execute one batch: load features per the route's precision, run the
/// artifact once, answer every member request.
fn run_batch(engine: &Engine, store: &ModelStore, metrics: &Metrics, batch: Batch) {
    let size = batch.requests.len();
    metrics.batches.fetch_add(1, Ordering::Relaxed);
    metrics.record_route(&batch.key.label());
    for r in &batch.requests {
        metrics.queue_wait.record(r.enqueued.elapsed());
    }

    match execute_route(engine, store, &batch.key) {
        Ok((logits, classes, load_time, exec_time)) => {
            metrics.load_time.record(load_time);
            metrics.exec_time.record(exec_time);
            let vals = match logits.as_f32() {
                Ok(v) => v,
                Err(e) => return fail_batch(metrics, batch, &e.to_string()),
            };
            for req in batch.requests {
                let predictions = req
                    .nodes
                    .iter()
                    .map(|&node| Prediction { node, class: argmax_row(vals, node, classes) })
                    .collect();
                let latency = req.enqueued.elapsed();
                metrics.latency.record(latency);
                metrics.completed.fetch_add(1, Ordering::Relaxed);
                let _ = req.reply.send(InferResponse {
                    id: req.id,
                    predictions,
                    latency,
                    batch_size: size,
                    error: None,
                });
            }
        }
        Err(e) => fail_batch(metrics, batch, &format!("{e:#}")),
    }
}

fn fail_batch(metrics: &Metrics, batch: Batch, msg: &str) {
    for req in batch.requests {
        metrics.failed.fetch_add(1, Ordering::Relaxed);
        let _ = req.reply.send(InferResponse {
            id: req.id,
            predictions: Vec::new(),
            latency: req.enqueued.elapsed(),
            batch_size: 0,
            error: Some(msg.to_string()),
        });
    }
}

/// Forward pass for one route. Returns (logits, classes, load, exec).
fn execute_route(
    engine: &Engine,
    store: &ModelStore,
    key: &RouteKey,
) -> Result<(Tensor, usize, std::time::Duration, std::time::Duration)> {
    let ds = store.dataset(&key.dataset)?;
    let weights = store.weights(&key.model, &key.dataset)?;
    let fstore = store.feature_store(&key.dataset)?;

    // Feature loading — the stage the paper's Table 3 measures. The store
    // re-reads from disk per batch (per-inference loading model).
    let (features, load_stats) = fstore.load(key.precision)?;
    let feat_tensor = match features {
        crate::quant::Features::Dense(t) => t,
        crate::quant::Features::Quantized { q, .. } => q,
    };

    let fwd = key.to_forward();
    let result = run_forward(engine, &ds, &weights, &fwd, Some(&feat_tensor))?;
    Ok((
        result.logits,
        ds.classes,
        load_stats.total(),
        result.stats.total(),
    ))
}

fn argmax_row(vals: &[f32], row: usize, classes: usize) -> i32 {
    let r = &vals[row * classes..(row + 1) * classes];
    r.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(k, _)| k as i32)
        .unwrap_or(0)
}

/// Convenience used by examples: run a route once outside the service and
/// report its test accuracy.
pub fn oneshot_accuracy(engine: &Engine, store: &ModelStore, key: &RouteKey) -> Result<f64> {
    let ds = store.dataset(&key.dataset)?;
    let weights = store.weights(&key.model, &key.dataset)?;
    let result = run_forward(engine, &ds, &weights, &key.to_forward(), None)?;
    accuracy(&ds, &result.logits)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_picks_max() {
        let vals = [0.1f32, 0.9, -1.0, 3.0, 2.0, 1.0];
        assert_eq!(argmax_row(&vals, 0, 3), 1);
        assert_eq!(argmax_row(&vals, 1, 3), 0);
    }
}
