//! The coordinator itself: bounded intake queue → batcher thread → the
//! persistent exec-layer worker pool, with a per-route plan cache so warm
//! routes never touch the feature store.
//!
//! Execution topology (vs the seed): the batcher hands each flushed
//! [`Batch`] straight to [`crate::exec::Pool`] (per-worker queues + work
//! stealing) instead of pushing it through a `Mutex<Receiver>` that every
//! worker contended; workers are spawned once at startup and parked
//! between batches.
//!
//! Cold routes additionally go through the async prefetcher: `submit`
//! kicks the route's plan build (feature staging + sampling) onto a
//! private prefetch pool *before* the request even reaches the batcher,
//! so staging overlaps the batching delay window and whatever SpMM the
//! workers are already running; by the time a worker executes the batch,
//! [`crate::exec::Prefetcher::fetch`] usually finds the plan warm. The
//! prefetch pool is deliberately separate from the batch pool — a batch
//! worker blocks in `fetch`, and it must never be able to block on a
//! build queued behind itself.

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::exec::{
    prepare_plan, ExecEnv, ExecPlan, PlanCache, PlanSpec, Pool, PrefetchStats, Prefetcher,
    ShardCacheRef, ShardKey, ShardLayout, ShardUnit,
};
use crate::graph::{Csr, DeltaReport, GraphDelta, ShardSpec};
use crate::quant::{Features, Precision};
use crate::runtime::{accuracy, run_forward, Backend, Dataset, Engine, ModelVals};
use crate::sampling::Strategy;
use crate::tensor::Tensor;
use crate::util::argmax_f32;

use super::batcher::{run_batcher_with, Batch, BatcherConfig};
use super::metrics::Metrics;
use super::request::{InferRequest, InferResponse, Prediction, RouteKey, SubmitError};
use super::store::ModelStore;

/// Coordinator construction knobs.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    pub batcher: BatcherConfig,
    /// Worker threads executing batches.
    pub workers: usize,
    /// Bounded intake queue length (backpressure beyond this).
    pub queue_depth: usize,
    /// Route plans kept warm (LRU beyond this many).
    pub plan_cache_capacity: usize,
    /// Threads staging cold route plans ahead of execution (0 disables
    /// prefetch; cold builds then run inline on the batch workers).
    pub prefetch_workers: usize,
    /// Row-shard host aggregation plans: partition each route's operand
    /// into working-set-budgeted shards with per-shard sampling and
    /// per-shard kernel dispatch (`--shards` / `--shard-budget`).
    /// `None` keeps single-working-set plans. Ignored by device
    /// backends, which aggregate in the compiled artifact.
    pub sharding: Option<ShardSpec>,
    /// Stage features through the zero-copy streaming path on
    /// host-aggregating backends (`FeatureStore::stage`: mmap row-block
    /// handles, lazy per-block dequant). `false` forces eager loads —
    /// the accuracy-conformance eval uses both settings to pin the
    /// streamed-vs-eager bitwise guarantee through the serving path.
    /// Ignored by device backends (always eager) and by fp32 routes
    /// (which never stream).
    pub streaming: bool,
    /// Prepared shard units kept warm across routes and precisions
    /// (LRU; units are pure graph structure, so one entry serves every
    /// route over the same operand).
    pub shard_cache_capacity: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            batcher: BatcherConfig::default(),
            workers: 2,
            queue_depth: 1024,
            plan_cache_capacity: 64,
            prefetch_workers: 1,
            sharding: None,
            streaming: true,
            shard_cache_capacity: 256,
        }
    }
}

/// What a route plan is keyed by. Narrower than [`RouteKey`]: the model
/// enters only through its **value family** ([`ModelVals`] — sampling is
/// structure-only, so `sage` and `gat` share one ones-valued operand),
/// and on device backends (fused in-kernel sampling) neither the family
/// nor width/strategy matter — so e.g. `gcn` and `sage` routes over one
/// dataset share a single cached feature load there.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
struct PlanKey {
    dataset: String,
    precision: Precision,
    /// Host-aggregating backends key the sampled ELL plan too.
    width: Option<usize>,
    strategy: Option<Strategy>,
    /// Aggregation value family (`None` for device routes, whose plans
    /// never carry a host operand).
    vals: Option<ModelVals>,
}

impl PlanKey {
    fn for_route(key: &RouteKey, host_aggregation: bool) -> PlanKey {
        if host_aggregation {
            PlanKey {
                dataset: key.dataset.clone(),
                precision: key.precision,
                width: key.width,
                // Strategy only matters when something is sampled — exact
                // host routes share one plan regardless of strategy.
                strategy: key.width.map(|_| key.strategy),
                vals: Some(ModelVals::of(&key.model)),
            }
        } else {
            PlanKey {
                dataset: key.dataset.clone(),
                precision: key.precision,
                width: None,
                strategy: None,
                vals: None,
            }
        }
    }
}

/// Point-in-time shard-unit cache counters (see
/// [`Coordinator::shard_stats`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ShardCacheStats {
    /// Prepared shard units currently resident.
    pub resident: usize,
    /// Unit lookups served warm (no re-partition, no re-sampling).
    pub hits: u64,
    /// Unit lookups that had to build.
    pub misses: u64,
    /// Units dropped by LRU overflow.
    pub evictions: u64,
    /// Unit lookups that found the resident entry tagged with a
    /// superseded graph epoch (a mutation raced its build). Counted per
    /// encounter — the entry stays resident until it is replaced by a
    /// rebuild, re-tagged by a later delta, or evicted.
    pub stale: u64,
}

/// What one [`Coordinator::apply_delta`] did — epoch advance, scope of
/// invalidation, and how much prepared state survived.
#[derive(Clone, Debug)]
pub struct DeltaOutcome {
    /// The dataset's epoch after the apply (unchanged for no-op deltas).
    pub epoch: u64,
    /// The splice report (touched rows, op counts).
    pub report: DeltaReport,
    /// Shard units invalidated (their shards were touched, or the
    /// layout was re-cut): these re-sample on next use.
    pub shards_resampled: usize,
    /// Shard units re-tagged to the new epoch without rebuilding —
    /// untouched shards staying warm, the scoped-invalidation win.
    pub shards_retained: usize,
    /// Whether a touched shard drifted past its working-set budget and
    /// forced the sticky layout to be thrown away (full re-partition on
    /// next build).
    pub repartitioned: bool,
    /// Route plans dropped (whole-graph objects: any change invalidates
    /// them, but their shard units above mostly survive).
    pub plans_invalidated: usize,
    /// Dropped route plans handed to the prefetcher for immediate
    /// re-staging against the new epoch (0 when prefetch is disabled).
    pub routes_restaged: usize,
}

/// Everything a pool worker needs to execute a batch.
struct WorkerCtx {
    backend: Backend,
    store: Arc<ModelStore>,
    metrics: Arc<Metrics>,
    plans: Arc<PlanCache<PlanKey, ExecPlan>>,
    /// Stages cold plans on its own pool; `None` when disabled.
    prefetch: Option<Prefetcher<PlanKey, ExecPlan>>,
    /// Sharding policy for host aggregation plans (`None` = unsharded).
    sharding: Option<ShardSpec>,
    /// Whether host plans stage features through the streaming path.
    streaming: bool,
    /// Prepared shard units, shared across routes/precisions — a plan
    /// build (inline or prefetched) samples only the cold shards.
    shard_units: Arc<PlanCache<ShardKey, ShardUnit>>,
    /// Sticky per-dataset shard layouts: the cut points are frozen at
    /// the first sharded build and reused across graph epochs, so a
    /// delta's shard-scoped invalidation has stable [`ShardKey`]s to
    /// aim at. Cleared (forcing a re-partition) on dataset-wide
    /// invalidation or working-set drift — the slot then keeps a
    /// **minimum derivation epoch**, so an in-flight build still
    /// holding a pre-re-cut dataset snapshot cannot resurrect the old
    /// cuts by re-deriving and inserting them.
    layouts: Mutex<HashMap<String, LayoutSlot>>,
    /// Serializes [`Coordinator::apply_delta`]: mutation is a
    /// read→splice→publish→invalidate sequence, and two concurrent
    /// appliers reading the same epoch would each publish "epoch N+1"
    /// with one delta's edits silently lost — and worse, tag two
    /// *different* graphs with the same epoch, which the versioned
    /// caches cannot tell apart. Mutations are rare; one lock is fine.
    delta_lock: Mutex<()>,
    env: ExecEnv,
}

/// One dataset's sticky-layout slot: the frozen cuts (if any) plus the
/// minimum graph epoch a newly derived layout must come from to be
/// allowed in. A drift re-cut (or dataset invalidation) clears the
/// layout and raises the floor to the current epoch, so a straggler
/// build still holding an older dataset snapshot derives its cuts
/// locally but cannot publish them — the next current-epoch build
/// re-partitions the mutated graph as intended.
#[derive(Default)]
struct LayoutSlot {
    layout: Option<Arc<ShardLayout>>,
    min_epoch: u64,
}

impl WorkerCtx {
    /// The dataset's frozen shard layout, created on first use from the
    /// builder's `(csr, epoch)` snapshot. A resident layout that no
    /// longer covers `csr`'s rows (a wholesale republish swapped in a
    /// differently-shaped graph) is never served — feeding it to
    /// `partition_fixed` would panic a worker. The derivation runs
    /// outside the lock (two racing first builds may both derive; first
    /// eligible insert wins — the cuts are deterministic in
    /// (csr, spec)).
    fn layout_for(
        &self,
        dataset: &str,
        csr: &crate::graph::Csr,
        epoch: u64,
        spec: &ShardSpec,
    ) -> Arc<ShardLayout> {
        if let Some(slot) = self.layouts.lock().unwrap().get(dataset) {
            if let Some(l) = &slot.layout {
                if l.covers(csr) {
                    return l.clone();
                }
            }
        }
        let built = Arc::new(ShardLayout::of(csr, spec));
        let mut layouts = self.layouts.lock().unwrap();
        let slot = layouts.entry(dataset.to_string()).or_default();
        match &slot.layout {
            Some(l) if l.covers(csr) => l.clone(),
            // Publish our derivation only if the snapshot it came from
            // is not older than the slot's floor; a sub-floor build
            // keeps its cuts private (its plan is tagged with a
            // superseded epoch and unreachable anyway).
            _ if epoch >= slot.min_epoch => {
                slot.layout = Some(built.clone());
                built
            }
            _ => built,
        }
    }

    /// Clear a dataset's sticky layout and forbid re-derivations from
    /// snapshots older than `min_epoch` (see [`LayoutSlot`]).
    fn clear_layout(&self, dataset: &str, min_epoch: u64) {
        let mut layouts = self.layouts.lock().unwrap();
        let slot = layouts.entry(dataset.to_string()).or_default();
        slot.layout = None;
        slot.min_epoch = slot.min_epoch.max(min_epoch);
    }
}

/// Handle to a running coordinator. Dropping it (or calling
/// [`Coordinator::shutdown`]) drains the pipeline and joins all threads.
pub struct Coordinator {
    intake: Option<mpsc::SyncSender<InferRequest>>,
    ctx: Arc<WorkerCtx>,
    next_id: AtomicU64,
    batcher: Option<JoinHandle<()>>,
    pool: Option<Arc<Pool>>,
}

impl Coordinator {
    /// Start over the PJRT engine (production path). Alias for
    /// [`Coordinator::start_with`] with [`Backend::Pjrt`].
    pub fn start(
        engine: Arc<Engine>,
        store: Arc<ModelStore>,
        cfg: CoordinatorConfig,
    ) -> Coordinator {
        Coordinator::start_with(Backend::Pjrt(engine), store, cfg)
    }

    /// Start the batcher + persistent worker pool over any [`Backend`].
    pub fn start_with(
        backend: Backend,
        store: Arc<ModelStore>,
        cfg: CoordinatorConfig,
    ) -> Coordinator {
        let plans = Arc::new(PlanCache::new(cfg.plan_cache_capacity));
        let prefetch = (cfg.prefetch_workers > 0)
            .then(|| Prefetcher::new(plans.clone(), Arc::new(Pool::new(cfg.prefetch_workers))));
        let ctx = Arc::new(WorkerCtx {
            backend,
            store,
            metrics: Arc::new(Metrics::new()),
            plans,
            prefetch,
            sharding: cfg.sharding,
            streaming: cfg.streaming,
            shard_units: Arc::new(PlanCache::new(cfg.shard_cache_capacity)),
            layouts: Mutex::new(HashMap::new()),
            delta_lock: Mutex::new(()),
            env: ExecEnv::detect(),
        });
        let pool = Arc::new(Pool::new(cfg.workers.max(1)));
        let (intake_tx, intake_rx) = mpsc::sync_channel::<InferRequest>(cfg.queue_depth);

        let batcher = {
            let pool = pool.clone();
            let ctx = ctx.clone();
            let bcfg = cfg.batcher;
            std::thread::Builder::new()
                .name("coordinator-batcher".into())
                .spawn(move || {
                    run_batcher_with(bcfg, intake_rx, move |batch| {
                        let ctx = ctx.clone();
                        pool.spawn(move || run_batch(&ctx, batch));
                        true
                    })
                })
                .expect("spawning batcher thread")
        };

        Coordinator {
            intake: Some(intake_tx),
            ctx,
            next_id: AtomicU64::new(1),
            batcher: Some(batcher),
            pool: Some(pool),
        }
    }

    /// Submit a query; returns the request id and the reply receiver.
    /// Fails fast with [`SubmitError::Busy`] when the queue is full.
    pub fn submit(
        &self,
        key: RouteKey,
        nodes: Vec<usize>,
    ) -> Result<(u64, mpsc::Receiver<InferResponse>), SubmitError> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let intake = self.intake.as_ref().ok_or(SubmitError::Closed)?;
        // Claim the route's prefetch slot before `key` moves into the
        // request: warm / already-staging routes coalesce on a cache peek
        // (no clones, no closure); a cold route's claim makes any batch
        // worker racing ahead wait for the build instead of duplicating
        // it. The build itself is only scheduled once the request is
        // admitted — a backpressure rejection drops the ticket, releasing
        // the claim without any storage work.
        let staging = self.ctx.prefetch.as_ref().and_then(|p| {
            let plan_key = PlanKey::for_route(&key, self.ctx.backend.aggregates_on_host());
            // Coalesce only on a plan at the dataset's *current* epoch:
            // a resident superseded-epoch plan (a mutation raced a stale
            // build) must not suppress staging, or the rebuild lands on
            // the batch worker's critical path.
            let epoch = self.ctx.store.dataset(&plan_key.dataset).ok()?.epoch;
            p.begin_versioned(plan_key.clone(), epoch).map(|ticket| (ticket, plan_key))
        });
        let (reply_tx, reply_rx) = mpsc::channel();
        let req = InferRequest { id, key, nodes, enqueued: Instant::now(), reply: reply_tx };
        self.ctx.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        match intake.try_send(req) {
            Ok(()) => {
                if let Some((ticket, plan_key)) = staging {
                    // Staging overlaps the batching window and whatever
                    // SpMM the workers are already executing. The build
                    // binds the dataset snapshot (and its epoch) when it
                    // runs, so the cached plan is tagged with the epoch
                    // of the graph it actually read.
                    let ctx = self.ctx.clone();
                    ticket.commit_versioned(move || build_plan_current(&ctx, &plan_key));
                }
                Ok((id, reply_rx))
            }
            Err(mpsc::TrySendError::Full(_)) => {
                self.ctx.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                Err(SubmitError::Busy)
            }
            Err(mpsc::TrySendError::Disconnected(_)) => Err(SubmitError::Closed),
        }
    }

    /// Blocking submit-and-wait convenience.
    pub fn infer(&self, key: RouteKey, nodes: Vec<usize>) -> Result<InferResponse> {
        let (_, rx) = self.submit(key, nodes).map_err(anyhow::Error::from)?;
        Ok(rx.recv()?)
    }

    /// Execute one route synchronously through the full serving data
    /// path — plan cache, prefetcher, sharded execution, backend — and
    /// return the raw logits tensor.
    ///
    /// This is the accuracy-conformance entry (`eval::run_eval`,
    /// `tests/accuracy.rs`): it resolves and executes the route exactly
    /// the way a batch worker does (the batched request path only adds
    /// grouping and per-node argmax on top), but hands back the logits
    /// so differential metrics can be computed against the exact oracle.
    /// Runs on the calling thread; plan-cache hit/miss and
    /// sharded-batch metrics are recorded as usual.
    pub fn route_logits(&self, key: &RouteKey) -> Result<Tensor> {
        let (logits, ..) = execute_route(&self.ctx, key)?;
        Ok(logits)
    }

    /// [`Coordinator::route_logits`], but also reporting the epoch and
    /// class count of the dataset snapshot the served plan actually
    /// bound. This is the **only** truthful way to label logits with an
    /// epoch: reading `store.dataset(..).epoch` before or after the
    /// execution races [`Coordinator::apply_delta`] and can tag
    /// epoch-N+1 logits as epoch N (or vice versa). The wire front-end
    /// and the shard-server replication path echo this value.
    pub fn route_logits_versioned(&self, key: &RouteKey) -> Result<(Tensor, u64, usize)> {
        let (logits, classes, epoch, ..) = execute_route(&self.ctx, key)?;
        Ok((logits, epoch, classes))
    }

    /// The dataset's shard-layout row cuts as `(start, end)` pairs —
    /// `[(0, n)]` when this coordinator is unsharded. Deterministic for
    /// a given (graph, spec): every process loading the same data
    /// computes the same cuts, which is what lets a router partition
    /// shard ownership without shipping the graph (docs/serving.md).
    pub fn shard_bounds(&self, dataset: &str) -> Result<Vec<(usize, usize)>> {
        let ds = self.ctx.store.dataset(dataset)?;
        match &self.ctx.sharding {
            Some(spec) => {
                let layout = self.ctx.layout_for(dataset, &ds.csr_gcn, ds.epoch, spec);
                Ok(layout.bounds().iter().map(|r| (r.start, r.end)).collect())
            }
            None => Ok(vec![(0, ds.n)]),
        }
    }

    pub fn metrics(&self) -> &Metrics {
        &self.ctx.metrics
    }

    /// Worker threads in the batch pool (constant for the coordinator's
    /// lifetime — workers are never re-spawned per batch).
    pub fn pool_workers(&self) -> usize {
        self.pool.as_ref().map(|p| p.worker_count()).unwrap_or(0)
    }

    /// Cached route plans currently warm.
    pub fn plan_cache_len(&self) -> usize {
        self.ctx.plans.len()
    }

    /// Shard-unit cache counters (all zeros until a sharded route
    /// builds). Units are shared across routes and precisions, so
    /// `hits` counts shards a plan build did *not* have to re-sample.
    pub fn shard_stats(&self) -> ShardCacheStats {
        let units = &self.ctx.shard_units;
        ShardCacheStats {
            resident: units.len(),
            hits: units.hits(),
            misses: units.misses(),
            evictions: units.evictions(),
            stale: units.stale(),
        }
    }

    /// Warm a route ahead of traffic: stage its plan (feature load +
    /// sampling + dispatch) on the prefetch pool without submitting a
    /// request. Returns `true` when a build was scheduled, `false` when
    /// the route was already warm/in-flight or prefetch is disabled.
    pub fn prefetch_route(&self, key: &RouteKey) -> bool {
        self.spawn_prefetch(key)
    }

    /// Prefetcher counters (all zeros when prefetch is disabled).
    pub fn prefetch_stats(&self) -> PrefetchStats {
        self.ctx.prefetch.as_ref().map(|p| p.stats()).unwrap_or_default()
    }

    /// Block until no prefetch build is queued or running (tests and
    /// warm-up scripts that want a deterministic cache state).
    pub fn wait_prefetch_idle(&self) {
        if let Some(p) = &self.ctx.prefetch {
            p.wait_idle();
        }
    }

    fn spawn_prefetch(&self, key: &RouteKey) -> bool {
        let plan_key = PlanKey::for_route(key, self.ctx.backend.aggregates_on_host());
        self.spawn_prefetch_key(plan_key)
    }

    fn spawn_prefetch_key(&self, plan_key: PlanKey) -> bool {
        let Some(p) = &self.ctx.prefetch else { return false };
        let Ok(ds) = self.ctx.store.dataset(&plan_key.dataset) else { return false };
        let Some(ticket) = p.begin_versioned(plan_key.clone(), ds.epoch) else { return false };
        let ctx = self.ctx.clone();
        ticket.commit_versioned(move || build_plan_current(&ctx, &plan_key));
        true
    }

    /// Drop every cached plan, shard unit, and the sticky shard layout
    /// of the route's **dataset** (republished data / rotated features);
    /// the next batch on any of its routes reloads from storage and
    /// re-partitions. Invalidation is per-dataset, not per-route,
    /// because sibling routes (other precisions, widths, models) share
    /// the same underlying graph and feature file — dropping only one
    /// would leave the others serving stale data. Returns whether any
    /// plan was resident.
    ///
    /// This is the blunt instrument (everything rebuilds). For live
    /// edge mutations prefer [`Coordinator::apply_delta`], which keeps
    /// untouched shards warm.
    pub fn invalidate_route(&self, key: &RouteKey) -> bool {
        // Floor the layout slot at the currently published epoch so an
        // in-flight build of a pre-invalidation snapshot cannot
        // re-publish the old cuts (if the dataset is unchanged the
        // re-derived cuts are identical anyway, so the floor only
        // matters when this invalidate follows a republish).
        let epoch =
            self.ctx.store.dataset(&key.dataset).map(|d| d.epoch).unwrap_or(u64::MAX);
        self.ctx.clear_layout(&key.dataset, epoch);
        self.ctx.shard_units.invalidate_matching(|k| k.tag == key.dataset);
        self.ctx.plans.invalidate_matching(|k| k.dataset == key.dataset) > 0
    }

    /// Drop every cached plan, shard unit, and layout.
    pub fn invalidate_all_routes(&self) {
        for name in self.ctx.store.dataset_names() {
            let epoch = self.ctx.store.dataset(&name).map(|d| d.epoch).unwrap_or(u64::MAX);
            self.ctx.clear_layout(&name, epoch);
        }
        self.ctx.plans.clear();
        self.ctx.shard_units.clear();
    }

    /// Apply a live edge delta to `dataset`: splice the CSR, advance
    /// the epoch, and invalidate **precisely** — only the shard units
    /// whose rows the delta touched are dropped (they re-sample, and
    /// their [`crate::sampling::shard_width`] uniform/skewed decision
    /// is re-evaluated, on next use); untouched units are re-tagged to
    /// the new epoch and stay warm, which [`Coordinator::shard_stats`]
    /// can prove. Route plans of the dataset are whole-graph objects,
    /// so they are dropped and immediately re-staged through the
    /// prefetcher (warm shard units make those rebuilds cheap).
    ///
    /// Ordering contract (the stale-plan fix depends on it): the new
    /// dataset is **published first**, then caches are invalidated.
    /// A plan builder serializes either before the publish (its plan is
    /// tagged with the old epoch — unreachable at the new one) or after
    /// (it reads the new graph). Either way no stale plan can be served
    /// at the new epoch; see `docs/mutation.md`.
    ///
    /// Consistency note: deltas edit stored values (for GCN routes the
    /// Â entries) directly; a weight policy that depends on degrees
    /// must emit the corresponding reweights itself. Live mutation is a
    /// host-aggregation feature — device artifacts are compiled against
    /// a fixed graph shape, so PJRT routes of a mutated dataset should
    /// be re-compiled (`make artifacts`) and republished instead.
    pub fn apply_delta(&self, dataset: &str, delta: &GraphDelta) -> Result<DeltaOutcome> {
        let ctx = &self.ctx;
        // Mutations serialize: concurrent appliers reading the same
        // epoch would lose edits and double-assign the epoch tag.
        let _mutating = ctx.delta_lock.lock().unwrap();
        let ds = ctx.store.dataset(dataset)?;
        let (spliced, report) = delta.apply_to(&ds.csr_gcn)?;
        let Some(csr_gcn) = spliced else {
            // Nothing changed: keep the epoch, keep every plan warm.
            return Ok(DeltaOutcome {
                epoch: ds.epoch,
                report,
                shards_resampled: 0,
                shards_retained: 0,
                repartitioned: false,
                plans_invalidated: 0,
                routes_restaged: 0,
            });
        };
        let epoch = ds.epoch + 1;
        let nnz = csr_gcn.nnz();
        // The feature tensors / labels / masks are copied here because
        // Dataset owns them; a delta never changes them, so Arc-ifying
        // those fields is the obvious follow-up if delta rates ever
        // make this copy show up.
        let new_ds = Dataset {
            nnz,
            epoch,
            csr_gcn,
            // Same structure with unit values (GraphSAGE's numerator).
            val_ones: vec![1.0f32; nnz],
            ..(*ds).clone()
        };
        // 1. Publish first — every lookup from here on binds epoch N+1.
        // Compare-and-publish: a concurrent *direct*
        // `ModelStore::publish_dataset` (wholesale republish — not
        // covered by the delta lock) would otherwise be silently
        // overwritten with a splice of data it just replaced.
        let new_ds = Arc::new(new_ds);
        if !ctx.store.publish_dataset_cas(dataset, ds.epoch, new_ds.clone())? {
            anyhow::bail!(
                "dataset {dataset:?} was republished while the delta applied \
                 (epoch moved past {}); re-apply against the new data",
                ds.epoch
            );
        }

        // 2. Shard units: atomically drop the touched shards' units and
        // re-tag the untouched ones from the superseded epoch to the
        // new one. One cache-lock acquisition (`advance_epoch`), so a
        // racing stale insert can neither land between the drop and the
        // re-tag nor be promoted — only entries verifiably built
        // against epoch N are revalidated at N+1.
        let layout = ctx.layouts.lock().unwrap().get(dataset).and_then(|s| s.layout.clone());
        let (mut shards_resampled, mut shards_retained) = (0usize, 0usize);
        let mut repartitioned = false;
        match layout {
            // A layout that no longer covers the graph (wholesale
            // republish changed the row count) is useless for scoping:
            // fall through to the drop-everything arm below.
            Some(layout) if layout.covers(&new_ds.csr_gcn) => {
                let affected = layout.affected_shards(&report.touched_rows);
                if layout.drifted(&new_ds.csr_gcn, &affected) {
                    // A touched shard outgrew its working-set budget:
                    // throw the cuts away (flooring the slot at the new
                    // epoch, so a straggler build of the pre-mutation
                    // snapshot cannot resurrect them); the next build
                    // re-partitions and re-samples everything.
                    ctx.clear_layout(dataset, epoch);
                    shards_resampled =
                        ctx.shard_units.invalidate_matching(|k| k.tag == dataset);
                    repartitioned = true;
                } else {
                    let hot: HashSet<(usize, usize)> = affected
                        .iter()
                        .map(|&i| {
                            let r = &layout.bounds()[i];
                            (r.start, r.end)
                        })
                        .collect();
                    (shards_resampled, shards_retained) = ctx.shard_units.advance_epoch(
                        |k| k.tag == dataset && hot.contains(&k.rows),
                        |k| k.tag == dataset,
                        ds.epoch,
                        epoch,
                    );
                }
            }
            // No sharded route built yet (or the resident layout is for
            // a differently-shaped graph): drop every unit; the next
            // build re-partitions.
            _ => {
                ctx.clear_layout(dataset, epoch);
                shards_resampled = ctx.shard_units.invalidate_matching(|k| k.tag == dataset);
            }
        }

        // 3. Route plans are whole-graph: drop the dataset's, keeping
        // the keys so step 4 can re-stage exactly those routes.
        let stale_keys = ctx.plans.take_matching(|k| k.dataset == dataset);

        ctx.metrics.graph_epochs.fetch_add(1, Ordering::Relaxed);
        ctx.metrics.shards_resampled.fetch_add(shards_resampled as u64, Ordering::Relaxed);
        ctx.metrics.shards_retained.fetch_add(shards_retained as u64, Ordering::Relaxed);

        // 4. Re-stage the dropped routes against the new epoch so the
        // next batch finds them warm (feature staging + the touched
        // shards' re-sampling run on the prefetch pool, off the batch
        // critical path).
        let mut routes_restaged = 0usize;
        for plan_key in &stale_keys {
            if self.spawn_prefetch_key(plan_key.clone()) {
                routes_restaged += 1;
            }
        }
        Ok(DeltaOutcome {
            epoch,
            report,
            shards_resampled,
            shards_retained,
            repartitioned,
            plans_invalidated: stale_keys.len(),
            routes_restaged,
        })
    }

    /// Drain the pipeline and join all threads.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        // Disconnect intake → batcher flushes pending groups into the
        // pool and exits → pool drains its queues → workers join.
        self.intake.take();
        if let Some(batcher) = self.batcher.take() {
            let _ = batcher.join();
        }
        if let Some(pool) = self.pool.take() {
            pool.wait_idle();
            // The batcher's clone is gone (joined above), so this drop is
            // the last reference and joins the parked workers.
            drop(pool);
        }
        // Let any still-running prefetch build finish cleanly; its pool
        // joins when the ctx (and with it the prefetcher) drops.
        if let Some(p) = &self.ctx.prefetch {
            p.wait_idle();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

/// Execute one batch: resolve the route plan (cache hit = no disk), run
/// the backend once, answer every member request.
fn run_batch(ctx: &WorkerCtx, batch: Batch) {
    let size = batch.requests.len();
    let metrics = &ctx.metrics;
    metrics.batches.fetch_add(1, Ordering::Relaxed);
    metrics.record_route(&batch.key.label());
    for r in &batch.requests {
        metrics.queue_wait.record(r.enqueued.elapsed());
    }

    match execute_route(ctx, &batch.key) {
        Ok((logits, classes, _epoch, load_time, exec_time, plan_hit)) => {
            metrics.load_time.record(load_time);
            metrics.exec_time.record(exec_time);
            if plan_hit {
                // Misses are counted where plans are actually built
                // (`build_plan`), which may be the prefetcher rather than
                // this worker; a hit here includes plans a prefetch
                // finished while the batch waited.
                metrics.plan_hits.fetch_add(1, Ordering::Relaxed);
            }
            let vals = match logits.as_f32() {
                Ok(v) => v,
                Err(e) => return fail_batch(metrics, batch, &e.to_string()),
            };
            let route_label = batch.key.label();
            for req in batch.requests {
                let predictions = req
                    .nodes
                    .iter()
                    .map(|&node| Prediction { node, class: argmax_row(vals, node, classes) })
                    .collect();
                let latency = req.enqueued.elapsed();
                metrics.latency.record(latency);
                metrics.record_route_latency(&route_label, latency);
                metrics.completed.fetch_add(1, Ordering::Relaxed);
                let _ = req.reply.send(InferResponse {
                    id: req.id,
                    predictions,
                    latency,
                    batch_size: size,
                    error: None,
                });
            }
        }
        Err(e) => fail_batch(metrics, batch, &format!("{e:#}")),
    }
}

fn fail_batch(metrics: &Metrics, batch: Batch, msg: &str) {
    for req in batch.requests {
        metrics.failed.fetch_add(1, Ordering::Relaxed);
        let _ = req.reply.send(InferResponse {
            id: req.id,
            predictions: Vec::new(),
            latency: req.enqueued.elapsed(),
            batch_size: 0,
            error: Some(msg.to_string()),
        });
    }
}

/// Build one route's plan from an already-bound dataset snapshot — the
/// cold path, whether it runs inline on a batch worker or ahead of time
/// on the prefetch pool. Counts itself as a plan miss (builds are the
/// meaningful "miss" once staging can happen off the critical path).
///
/// The caller fetches `ds` **once** and uses `ds.epoch` for the cache
/// transaction; building from that same snapshot is what makes the
/// epoch tag truthful — the plan can never claim an epoch whose graph
/// it did not read.
fn build_plan(ctx: &WorkerCtx, key: &PlanKey, ds: &Dataset) -> Result<ExecPlan> {
    ctx.metrics.plan_misses.fetch_add(1, Ordering::Relaxed);
    let fstore = ctx.store.feature_store(&key.dataset)?;
    let host_aggregation = ctx.backend.aggregates_on_host();
    // Sharding is a host-aggregation concern; device artifacts aggregate
    // in-kernel and keep the single-operand plan.
    let shard = if host_aggregation { ctx.sharding } else { None };
    // Sticky layout: the dataset's frozen cuts (created here on first
    // sharded use). Mutated epochs reuse them so untouched shard units
    // keep their keys.
    let layout = shard.map(|spec| ctx.layout_for(&key.dataset, &ds.csr_gcn, ds.epoch, &spec));
    // The plan's operand carries the route's value family: Â entries for
    // GCN, all-ones for the rest of the zoo (structure identical either
    // way — GAT substitutes per-edge α at execution time and max-pool
    // never reads values, so one ones-valued plan serves them all).
    let vals = key.vals.unwrap_or(ModelVals::Gcn);
    let ones_csr;
    let csr: &Csr = match vals {
        ModelVals::Gcn => &ds.csr_gcn,
        ModelVals::Ones => {
            ones_csr = Csr { val: ds.val_ones.clone(), ..ds.csr_gcn.clone() };
            &ones_csr
        }
    };
    let spec = PlanSpec {
        csr,
        // PlanKey width/strategy are pre-normalized for the backend.
        width: key.width,
        strategy: key.strategy.unwrap_or(Strategy::Aes),
        host_ell: host_aggregation,
        // Host aggregation consumes features row-block-wise, so the plan
        // can hold a zero-copy streamed handle; device artifacts need the
        // eagerly materialized tensor. The eval harness flips
        // `CoordinatorConfig::streaming` off to pin streamed-vs-eager
        // bitwise equality through this exact path.
        stream: host_aggregation && ctx.streaming,
        shard,
        shard_bounds: layout.as_deref().map(|l| l.bounds()),
        // Units are keyed by dataset + value family + width + strategy +
        // row range (and epoch-versioned), so a build for one precision
        // warms every sibling route's shards — across the whole model
        // zoo when the routes share a value family.
        shard_cache: shard.map(|_| ShardCacheRef {
            units: &ctx.shard_units,
            tag: key.dataset.as_str(),
            epoch: ds.epoch,
            vals,
        }),
    };
    prepare_plan(&fstore, key.precision, &spec, ds.feats, &ctx.env)
}

/// [`build_plan`] against the store's **current** snapshot, reporting
/// the epoch it bound — the prefetch-pool builder
/// ([`crate::exec::PrefetchTicket::commit_versioned`] tags the cached
/// plan with exactly this epoch).
fn build_plan_current(ctx: &WorkerCtx, key: &PlanKey) -> Result<(ExecPlan, u64)> {
    let ds = ctx.store.dataset(&key.dataset)?;
    let plan = build_plan(ctx, key, &ds)?;
    Ok((plan, ds.epoch))
}

/// Forward pass for one route through its (possibly cached) plan.
/// Returns (logits, classes, epoch, load, exec, plan_hit) — `epoch` is
/// the dataset snapshot the whole execution bound, i.e. the only epoch
/// this result may truthfully be labeled with.
///
/// Cold route: the plan build performs the instrumented feature staging —
/// the stage the paper's Table 3 measures. With prefetch enabled the
/// build usually ran (or is running) on the prefetch pool already; this
/// worker waits for it instead of duplicating the storage read. Warm
/// route: the plan comes from memory and `load` is zero, which is the
/// whole point of the cache.
fn execute_route(
    ctx: &WorkerCtx,
    key: &RouteKey,
) -> Result<(Tensor, usize, u64, Duration, Duration, bool)> {
    // One dataset fetch per execution: the epoch of this snapshot is
    // the epoch the whole batch runs at — plan resolution, shard units,
    // and the forward all read this same `Arc`, so a delta landing
    // mid-batch cannot tear the execution across epochs.
    let ds = ctx.store.dataset(&key.dataset)?;
    let weights = ctx.store.weights(&key.model, &key.dataset)?;

    let host_aggregation = ctx.backend.aggregates_on_host();
    let plan_key = PlanKey::for_route(key, host_aggregation);
    let (plan, hit) = match &ctx.prefetch {
        Some(p) => p.fetch_versioned(&plan_key, ds.epoch, || build_plan(ctx, &plan_key, &ds))?,
        None => ctx.plans.get_or_try_insert_versioned(&plan_key, ds.epoch, || {
            build_plan(ctx, &plan_key, &ds)
        })?,
    };
    if plan.sharded.is_some() {
        ctx.metrics.sharded_batches.fetch_add(1, Ordering::Relaxed);
    }

    let feat_tensor = match &plan.features {
        Features::Dense(t) => Some(t),
        Features::Quantized { q, .. } => Some(q),
        // The host backend streams row-blocks straight from the plan's
        // handle; there is no materialized tensor to pass.
        Features::Streamed(_) => None,
    };

    let fwd = key.to_forward();
    let result = ctx.backend.forward(&ds, &weights, &fwd, feat_tensor, Some(&*plan), &ctx.env)?;
    let load_time = if hit { Duration::ZERO } else { plan.load_stats.total() };
    Ok((result.logits, ds.classes, ds.epoch, load_time, result.stats.total(), hit))
}

/// NaN-safe per-node argmax (deterministic: NaN loses, ties break low,
/// all-NaN rows yield class 0).
fn argmax_row(vals: &[f32], row: usize, classes: usize) -> i32 {
    argmax_f32(&vals[row * classes..(row + 1) * classes]) as i32
}

/// Convenience used by examples: run a route once outside the service and
/// report its test accuracy.
pub fn oneshot_accuracy(engine: &Engine, store: &ModelStore, key: &RouteKey) -> Result<f64> {
    let ds = store.dataset(&key.dataset)?;
    let weights = store.weights(&key.model, &key.dataset)?;
    let result = run_forward(engine, &ds, &weights, &key.to_forward(), None)?;
    accuracy(&ds, &result.logits)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_picks_max() {
        let vals = [0.1f32, 0.9, -1.0, 3.0, 2.0, 1.0];
        assert_eq!(argmax_row(&vals, 0, 3), 1);
        assert_eq!(argmax_row(&vals, 1, 3), 0);
    }

    #[test]
    fn argmax_survives_nan_logits() {
        // The seed panicked the worker thread here (partial_cmp unwrap).
        let vals = [f32::NAN, 0.5, 0.2, f32::NAN, f32::NAN, f32::NAN];
        assert_eq!(argmax_row(&vals, 0, 3), 1);
        // All-NaN row: deterministic class 0, not a panic.
        assert_eq!(argmax_row(&vals, 1, 3), 0);
    }

    #[test]
    fn plan_key_collapses_device_routes() {
        let mk = |model: &str, width, strategy, precision| RouteKey {
            model: model.into(),
            dataset: "cora".into(),
            width,
            strategy,
            precision,
        };
        // Device backends: one plan per (dataset, precision).
        let a = PlanKey::for_route(&mk("gcn", Some(16), Strategy::Aes, Precision::F32), false);
        let b = PlanKey::for_route(&mk("gcn", Some(64), Strategy::Sfs, Precision::F32), false);
        assert_eq!(a, b);
        let c = PlanKey::for_route(&mk("gcn", Some(16), Strategy::Aes, Precision::U8Device), false);
        assert_ne!(a, c);
        // ...and the model collapses too: device artifacts aggregate
        // in-kernel, so the plan (a feature load) is model-free.
        let a2 = PlanKey::for_route(&mk("sage", Some(16), Strategy::Aes, Precision::F32), false);
        assert_eq!(a, a2);
        // Host backends: the sampled plan differs per width/strategy.
        let d = PlanKey::for_route(&mk("gcn", Some(16), Strategy::Aes, Precision::F32), true);
        let e = PlanKey::for_route(&mk("gcn", Some(64), Strategy::Aes, Precision::F32), true);
        assert_ne!(d, e);
        // ...but exact host routes ignore the (unused) strategy field.
        let f = PlanKey::for_route(&mk("gcn", None, Strategy::Aes, Precision::F32), true);
        let g = PlanKey::for_route(&mk("gcn", None, Strategy::Sfs, Precision::F32), true);
        assert_eq!(f, g);
        // Host plans key on the value family, not the model name: gcn
        // (Â operand) differs from sage, but sage and gat share the
        // ones-valued operand plan.
        let h = PlanKey::for_route(&mk("sage", None, Strategy::Aes, Precision::F32), true);
        assert_ne!(f, h);
        let i = PlanKey::for_route(&mk("gat", None, Strategy::Aes, Precision::F32), true);
        assert_eq!(h, i);
    }
}
