//! The coordinator itself: bounded intake queue → batcher thread → the
//! persistent exec-layer worker pool, with a per-route plan cache so warm
//! routes never touch the feature store.
//!
//! Execution topology (vs the seed): the batcher hands each flushed
//! [`Batch`] straight to [`crate::exec::Pool`] (per-worker queues + work
//! stealing) instead of pushing it through a `Mutex<Receiver>` that every
//! worker contended; workers are spawned once at startup and parked
//! between batches.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::exec::{prepare_plan, ExecEnv, ExecPlan, PlanCache, PlanSpec, Pool};
use crate::quant::{Features, Precision};
use crate::runtime::{accuracy, run_forward, Backend, Engine};
use crate::sampling::Strategy;
use crate::tensor::Tensor;
use crate::util::argmax_f32;

use super::batcher::{run_batcher_with, Batch, BatcherConfig};
use super::metrics::Metrics;
use super::request::{InferRequest, InferResponse, Prediction, RouteKey, SubmitError};
use super::store::ModelStore;

/// Coordinator construction knobs.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    pub batcher: BatcherConfig,
    /// Worker threads executing batches.
    pub workers: usize,
    /// Bounded intake queue length (backpressure beyond this).
    pub queue_depth: usize,
    /// Route plans kept warm (LRU beyond this many).
    pub plan_cache_capacity: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            batcher: BatcherConfig::default(),
            workers: 2,
            queue_depth: 1024,
            plan_cache_capacity: 64,
        }
    }
}

/// What a route plan is keyed by. Narrower than [`RouteKey`]: the model
/// never changes the feature tensor, and on device backends (fused
/// in-kernel sampling) neither do width/strategy — so e.g. `gcn` and
/// `sage` routes over one dataset share a single cached feature load.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
struct PlanKey {
    dataset: String,
    precision: Precision,
    /// Host-aggregating backends key the sampled ELL plan too.
    width: Option<usize>,
    strategy: Option<Strategy>,
}

impl PlanKey {
    fn for_route(key: &RouteKey, host_aggregation: bool) -> PlanKey {
        if host_aggregation {
            PlanKey {
                dataset: key.dataset.clone(),
                precision: key.precision,
                width: key.width,
                // Strategy only matters when something is sampled — exact
                // host routes share one plan regardless of strategy.
                strategy: key.width.map(|_| key.strategy),
            }
        } else {
            PlanKey {
                dataset: key.dataset.clone(),
                precision: key.precision,
                width: None,
                strategy: None,
            }
        }
    }
}

/// Everything a pool worker needs to execute a batch.
struct WorkerCtx {
    backend: Backend,
    store: Arc<ModelStore>,
    metrics: Arc<Metrics>,
    plans: PlanCache<PlanKey, ExecPlan>,
    env: ExecEnv,
}

/// Handle to a running coordinator. Dropping it (or calling
/// [`Coordinator::shutdown`]) drains the pipeline and joins all threads.
pub struct Coordinator {
    intake: Option<mpsc::SyncSender<InferRequest>>,
    ctx: Arc<WorkerCtx>,
    next_id: AtomicU64,
    batcher: Option<JoinHandle<()>>,
    pool: Option<Arc<Pool>>,
}

impl Coordinator {
    /// Start over the PJRT engine (production path). Alias for
    /// [`Coordinator::start_with`] with [`Backend::Pjrt`].
    pub fn start(engine: Arc<Engine>, store: Arc<ModelStore>, cfg: CoordinatorConfig) -> Coordinator {
        Coordinator::start_with(Backend::Pjrt(engine), store, cfg)
    }

    /// Start the batcher + persistent worker pool over any [`Backend`].
    pub fn start_with(backend: Backend, store: Arc<ModelStore>, cfg: CoordinatorConfig) -> Coordinator {
        let ctx = Arc::new(WorkerCtx {
            backend,
            store,
            metrics: Arc::new(Metrics::new()),
            plans: PlanCache::new(cfg.plan_cache_capacity),
            env: ExecEnv::detect(),
        });
        let pool = Arc::new(Pool::new(cfg.workers.max(1)));
        let (intake_tx, intake_rx) = mpsc::sync_channel::<InferRequest>(cfg.queue_depth);

        let batcher = {
            let pool = pool.clone();
            let ctx = ctx.clone();
            let bcfg = cfg.batcher;
            std::thread::Builder::new()
                .name("coordinator-batcher".into())
                .spawn(move || {
                    run_batcher_with(bcfg, intake_rx, move |batch| {
                        let ctx = ctx.clone();
                        pool.spawn(move || run_batch(&ctx, batch));
                        true
                    })
                })
                .expect("spawning batcher thread")
        };

        Coordinator {
            intake: Some(intake_tx),
            ctx,
            next_id: AtomicU64::new(1),
            batcher: Some(batcher),
            pool: Some(pool),
        }
    }

    /// Submit a query; returns the request id and the reply receiver.
    /// Fails fast with [`SubmitError::Busy`] when the queue is full.
    pub fn submit(
        &self,
        key: RouteKey,
        nodes: Vec<usize>,
    ) -> Result<(u64, mpsc::Receiver<InferResponse>), SubmitError> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (reply_tx, reply_rx) = mpsc::channel();
        let req = InferRequest { id, key, nodes, enqueued: Instant::now(), reply: reply_tx };
        let intake = self.intake.as_ref().ok_or(SubmitError::Closed)?;
        self.ctx.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        match intake.try_send(req) {
            Ok(()) => Ok((id, reply_rx)),
            Err(mpsc::TrySendError::Full(_)) => {
                self.ctx.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                Err(SubmitError::Busy)
            }
            Err(mpsc::TrySendError::Disconnected(_)) => Err(SubmitError::Closed),
        }
    }

    /// Blocking submit-and-wait convenience.
    pub fn infer(&self, key: RouteKey, nodes: Vec<usize>) -> Result<InferResponse> {
        let (_, rx) = self.submit(key, nodes).map_err(anyhow::Error::from)?;
        Ok(rx.recv()?)
    }

    pub fn metrics(&self) -> &Metrics {
        &self.ctx.metrics
    }

    /// Worker threads in the batch pool (constant for the coordinator's
    /// lifetime — workers are never re-spawned per batch).
    pub fn pool_workers(&self) -> usize {
        self.pool.as_ref().map(|p| p.worker_count()).unwrap_or(0)
    }

    /// Cached route plans currently warm.
    pub fn plan_cache_len(&self) -> usize {
        self.ctx.plans.len()
    }

    /// Drop one route's cached plan (dataset republished / features
    /// rotated); the next batch on it reloads from storage.
    pub fn invalidate_route(&self, key: &RouteKey) -> bool {
        self.ctx
            .plans
            .invalidate(&PlanKey::for_route(key, self.ctx.backend.aggregates_on_host()))
    }

    /// Drop every cached plan.
    pub fn invalidate_all_routes(&self) {
        self.ctx.plans.clear();
    }

    /// Drain the pipeline and join all threads.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        // Disconnect intake → batcher flushes pending groups into the
        // pool and exits → pool drains its queues → workers join.
        self.intake.take();
        if let Some(batcher) = self.batcher.take() {
            let _ = batcher.join();
        }
        if let Some(pool) = self.pool.take() {
            pool.wait_idle();
            // The batcher's clone is gone (joined above), so this drop is
            // the last reference and joins the parked workers.
            drop(pool);
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

/// Execute one batch: resolve the route plan (cache hit = no disk), run
/// the backend once, answer every member request.
fn run_batch(ctx: &WorkerCtx, batch: Batch) {
    let size = batch.requests.len();
    let metrics = &ctx.metrics;
    metrics.batches.fetch_add(1, Ordering::Relaxed);
    metrics.record_route(&batch.key.label());
    for r in &batch.requests {
        metrics.queue_wait.record(r.enqueued.elapsed());
    }

    match execute_route(ctx, &batch.key) {
        Ok((logits, classes, load_time, exec_time, plan_hit)) => {
            metrics.load_time.record(load_time);
            metrics.exec_time.record(exec_time);
            if plan_hit {
                metrics.plan_hits.fetch_add(1, Ordering::Relaxed);
            } else {
                metrics.plan_misses.fetch_add(1, Ordering::Relaxed);
            }
            let vals = match logits.as_f32() {
                Ok(v) => v,
                Err(e) => return fail_batch(metrics, batch, &e.to_string()),
            };
            for req in batch.requests {
                let predictions = req
                    .nodes
                    .iter()
                    .map(|&node| Prediction { node, class: argmax_row(vals, node, classes) })
                    .collect();
                let latency = req.enqueued.elapsed();
                metrics.latency.record(latency);
                metrics.completed.fetch_add(1, Ordering::Relaxed);
                let _ = req.reply.send(InferResponse {
                    id: req.id,
                    predictions,
                    latency,
                    batch_size: size,
                    error: None,
                });
            }
        }
        Err(e) => fail_batch(metrics, batch, &format!("{e:#}")),
    }
}

fn fail_batch(metrics: &Metrics, batch: Batch, msg: &str) {
    for req in batch.requests {
        metrics.failed.fetch_add(1, Ordering::Relaxed);
        let _ = req.reply.send(InferResponse {
            id: req.id,
            predictions: Vec::new(),
            latency: req.enqueued.elapsed(),
            batch_size: 0,
            error: Some(msg.to_string()),
        });
    }
}

/// Forward pass for one route through its (possibly cached) plan.
/// Returns (logits, classes, load, exec, plan_hit).
///
/// Cold route: the plan build performs the instrumented feature load —
/// the stage the paper's Table 3 measures — and its time is charged to
/// this batch. Warm route: the plan comes from memory and `load` is zero,
/// which is the whole point of the cache.
fn execute_route(
    ctx: &WorkerCtx,
    key: &RouteKey,
) -> Result<(Tensor, usize, Duration, Duration, bool)> {
    let ds = ctx.store.dataset(&key.dataset)?;
    let weights = ctx.store.weights(&key.model, &key.dataset)?;

    let host_aggregation = ctx.backend.aggregates_on_host();
    let plan_key = PlanKey::for_route(key, host_aggregation);
    let (plan, hit) = ctx.plans.get_or_try_insert(&plan_key, || {
        let fstore = ctx.store.feature_store(&key.dataset)?;
        let spec = PlanSpec {
            csr: &ds.csr_gcn,
            width: if host_aggregation { key.width } else { None },
            strategy: key.strategy,
            host_ell: host_aggregation,
        };
        prepare_plan(&fstore, key.precision, &spec, ds.feats, &ctx.env)
    })?;

    let feat_tensor = match &plan.features {
        Features::Dense(t) => t,
        Features::Quantized { q, .. } => q,
    };

    let fwd = key.to_forward();
    let result = ctx.backend.forward(
        &ds,
        &weights,
        &fwd,
        Some(feat_tensor),
        Some(&*plan),
        &ctx.env,
    )?;
    let load_time = if hit { Duration::ZERO } else { plan.load_stats.total() };
    Ok((result.logits, ds.classes, load_time, result.stats.total(), hit))
}

/// NaN-safe per-node argmax (deterministic: NaN loses, ties break low,
/// all-NaN rows yield class 0).
fn argmax_row(vals: &[f32], row: usize, classes: usize) -> i32 {
    argmax_f32(&vals[row * classes..(row + 1) * classes]) as i32
}

/// Convenience used by examples: run a route once outside the service and
/// report its test accuracy.
pub fn oneshot_accuracy(engine: &Engine, store: &ModelStore, key: &RouteKey) -> Result<f64> {
    let ds = store.dataset(&key.dataset)?;
    let weights = store.weights(&key.model, &key.dataset)?;
    let result = run_forward(engine, &ds, &weights, &key.to_forward(), None)?;
    accuracy(&ds, &result.logits)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_picks_max() {
        let vals = [0.1f32, 0.9, -1.0, 3.0, 2.0, 1.0];
        assert_eq!(argmax_row(&vals, 0, 3), 1);
        assert_eq!(argmax_row(&vals, 1, 3), 0);
    }

    #[test]
    fn argmax_survives_nan_logits() {
        // The seed panicked the worker thread here (partial_cmp unwrap).
        let vals = [f32::NAN, 0.5, 0.2, f32::NAN, f32::NAN, f32::NAN];
        assert_eq!(argmax_row(&vals, 0, 3), 1);
        // All-NaN row: deterministic class 0, not a panic.
        assert_eq!(argmax_row(&vals, 1, 3), 0);
    }

    #[test]
    fn plan_key_collapses_device_routes() {
        let mk = |width, strategy, precision| RouteKey {
            model: "gcn".into(),
            dataset: "cora".into(),
            width,
            strategy,
            precision,
        };
        // Device backends: one plan per (dataset, precision).
        let a = PlanKey::for_route(&mk(Some(16), Strategy::Aes, Precision::F32), false);
        let b = PlanKey::for_route(&mk(Some(64), Strategy::Sfs, Precision::F32), false);
        assert_eq!(a, b);
        let c = PlanKey::for_route(&mk(Some(16), Strategy::Aes, Precision::U8Device), false);
        assert_ne!(a, c);
        // Host backends: the sampled plan differs per width/strategy.
        let d = PlanKey::for_route(&mk(Some(16), Strategy::Aes, Precision::F32), true);
        let e = PlanKey::for_route(&mk(Some(64), Strategy::Aes, Precision::F32), true);
        assert_ne!(d, e);
        // ...but exact host routes ignore the (unused) strategy field.
        let f = PlanKey::for_route(&mk(None, Strategy::Aes, Precision::F32), true);
        let g = PlanKey::for_route(&mk(None, Strategy::Sfs, Precision::F32), true);
        assert_eq!(f, g);
    }
}
