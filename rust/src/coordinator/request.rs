//! Request/response types and the routing key.

use std::sync::mpsc;
use std::time::{Duration, Instant};

use crate::quant::Precision;
use crate::runtime::ForwardRequest;
use crate::sampling::Strategy;

/// Routing key: everything that determines which compiled artifact (and
/// which feature representation) serves a request. Requests with equal
/// keys are batched into one forward pass.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct RouteKey {
    pub model: String,
    pub dataset: String,
    /// None → exact baseline; Some(w) → sampled with shared-memory width w.
    pub width: Option<usize>,
    pub strategy: Strategy,
    pub precision: Precision,
}

impl RouteKey {
    pub fn to_forward(&self) -> ForwardRequest {
        ForwardRequest {
            model: self.model.clone(),
            dataset: self.dataset.clone(),
            width: self.width,
            strategy: self.strategy,
            precision: self.precision,
        }
    }

    /// Human-readable key, also the metrics label.
    pub fn label(&self) -> String {
        format!(
            "{}/{}/{}/{}/{}",
            self.model,
            self.dataset,
            self.width.map(|w| format!("w{w}")).unwrap_or_else(|| "exact".into()),
            self.strategy.name(),
            self.precision.name(),
        )
    }
}

/// Predicted class for one queried node.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Prediction {
    pub node: usize,
    pub class: i32,
}

/// A node-classification query: which nodes to classify under which route.
#[derive(Debug)]
pub struct InferRequest {
    pub id: u64,
    pub key: RouteKey,
    pub nodes: Vec<usize>,
    pub enqueued: Instant,
    pub reply: mpsc::Sender<InferResponse>,
}

/// The answer to one [`InferRequest`].
#[derive(Clone, Debug)]
pub struct InferResponse {
    pub id: u64,
    pub predictions: Vec<Prediction>,
    /// End-to-end latency (enqueue → reply).
    pub latency: Duration,
    /// How many requests shared the forward pass that served this one.
    pub batch_size: usize,
    /// Error message if the execution failed.
    pub error: Option<String>,
}

/// Why a submit was rejected.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// Bounded queue is full — backpressure; caller should retry later.
    Busy,
    /// Coordinator is shutting down.
    Closed,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Busy => write!(f, "queue full (backpressure)"),
            SubmitError::Closed => write!(f, "coordinator closed"),
        }
    }
}

impl std::error::Error for SubmitError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn label_is_stable() {
        let k = RouteKey {
            model: "gcn".into(),
            dataset: "cora".into(),
            width: Some(64),
            strategy: Strategy::Aes,
            precision: Precision::U8Device,
        };
        assert_eq!(k.label(), "gcn/cora/w64/aes/u8-device");
        let k2 = RouteKey { width: None, ..k.clone() };
        assert_eq!(k2.label(), "gcn/cora/exact/aes/u8-device");
    }

    #[test]
    fn equal_keys_hash_equal() {
        use std::collections::HashSet;
        let k = RouteKey {
            model: "gcn".into(),
            dataset: "cora".into(),
            width: Some(16),
            strategy: Strategy::Afs,
            precision: Precision::F32,
        };
        let mut set = HashSet::new();
        set.insert(k.clone());
        assert!(set.contains(&k));
        assert!(!set.contains(&RouteKey { width: Some(32), ..k }));
    }
}
