//! The shard router: multi-process sharded serving over the wire
//! protocol (docs/serving.md).
//!
//! A [`ShardRouter`] listens for ordinary client requests (`logits` /
//! `infer` / `mutate` / ops) and serves them by scatter/gathering
//! the shard plane (`shard_logits` / `shard_infer` / `apply_delta`)
//! across a fleet of `repro shard-server` worker processes. Each worker
//! holds a full replica of the datasets but **owns** only a subset of
//! the shard-layout row ranges; ownership governs which rows cross the
//! wire and which worker answers for them, while the forward pass on
//! each worker stays complete (multi-layer aggregation needs every
//! row's neighborhood — restricting execution to owned rows would
//! change the bits, and bitwise conformance with the single-process
//! coordinator is the contract the eval harness checks).
//!
//! # Placement
//!
//! The shard universe comes from the workers themselves: `status`
//! reports each dataset's `shard_bounds`, the deterministic row cuts of
//! the sticky [`crate::exec::ShardLayout`] — every worker loading the
//! same data derives the same cuts, so the router learns the partition
//! without ever shipping a graph. Shards are assigned round-robin over
//! the workers; on worker death they are re-assigned over the
//! survivors (any replica can serve any shard, so re-placement is a
//! routing change plus a catch-up, never a data copy).
//!
//! # Replication: the delta log
//!
//! A client `mutate` is broadcast to every live worker as an
//! `apply_delta` log entry tagged with the epoch it must produce
//! (`head + 1` — epochs are totally ordered and CAS-published, PR 5).
//! The router answers the client only after **all** live workers ack,
//! which is what makes reads-after-writes exact: a subsequent read is
//! labeled `head`, and every worker that can serve it has already
//! acked `head`. Entries that advanced the epoch are appended to an
//! in-memory log; per-(worker, dataset) **watermarks** record the last
//! epoch each worker acked.
//!
//! A worker found lagging (a served epoch below `head`, or a survivor
//! inheriting a dead worker's shards) is caught up by replaying log
//! entries above its watermark, in order. Replay is idempotent on the
//! worker side — a worker already at an entry's epoch acks without
//! re-applying — so the router can always over-replay after a partial
//! failure.
//!
//! # Failover
//!
//! A worker is `live` until an I/O failure (EOF, reset, timeout) marks
//! it dead: reads then heal lazily — the next request re-places the
//! dead worker's shards onto survivors, replays from their watermarks,
//! and retries. Workers never rejoin a running router (restart the
//! router to re-bootstrap). With zero live workers the router stays up
//! and answers errors — an operator can still reach `status`.

use std::collections::BTreeMap;
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::util::JsonValue;

use super::net::{FrameHandler, ListenerShared, WireListener};
use super::request::RouteKey;
use super::wire::{self, WireRequest};

/// Router knobs.
#[derive(Clone, Debug)]
pub struct RouterConfig {
    /// In-flight data-plane requests beyond which new ones are shed
    /// (same semantics as [`super::NetConfig::high_water`]).
    pub high_water: usize,
    /// Per-frame byte cap for client connections.
    pub max_frame: usize,
    /// Connect/read timeout for worker calls; a worker silent for this
    /// long is treated as dead.
    pub worker_timeout: Duration,
    /// How long bootstrap keeps retrying the first worker `status`
    /// (workers may still be binding when the router starts).
    pub bootstrap_timeout: Duration,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            high_water: 256,
            max_frame: wire::MAX_FRAME,
            worker_timeout: Duration::from_secs(120),
            bootstrap_timeout: Duration::from_secs(30),
        }
    }
}

/// One replication-log entry: the delta text and the epoch it produced.
struct LogEntry {
    epoch: u64,
    ops: Vec<String>,
}

/// Per-dataset routing + replication state. One mutex per dataset:
/// reads snapshot under it and scatter without it; mutation and
/// catch-up (both rare) hold it across their worker I/O, which is what
/// serializes the log.
struct DatasetState {
    nodes: usize,
    classes: usize,
    /// Shard-layout row cuts, identical on every worker.
    bounds: Vec<(usize, usize)>,
    /// Owning worker index per shard.
    placement: Vec<usize>,
    /// Highest epoch the router has served a write for.
    head: u64,
    /// Last epoch each worker acked (indexed like `workers`).
    watermarks: Vec<u64>,
    log: Vec<LogEntry>,
}

/// A connection to one shard worker. The stream is created lazily and
/// re-dialed once per call on failure (a restarted listener or a stale
/// keep-alive), so transient breakage costs one retry, not a death.
struct WorkerLink {
    addr: String,
    conn: Mutex<Option<TcpStream>>,
    alive: AtomicBool,
}

impl WorkerLink {
    fn dial(addr: &str, timeout: Duration) -> Result<TcpStream> {
        let sock = addr
            .to_socket_addrs()
            .with_context(|| format!("resolving worker address {addr}"))?
            .next()
            .with_context(|| format!("worker address {addr} resolved to nothing"))?;
        let stream = TcpStream::connect_timeout(&sock, timeout)
            .with_context(|| format!("connecting to worker {addr}"))?;
        let _ = stream.set_nodelay(true);
        let _ = stream.set_read_timeout(Some(timeout));
        let _ = stream.set_write_timeout(Some(timeout));
        Ok(stream)
    }

    /// One request/response round-trip, re-dialing once on failure.
    fn call(&self, req: &WireRequest, timeout: Duration) -> Result<JsonValue> {
        let mut guard = self.conn.lock().unwrap();
        if let Some(stream) = guard.as_mut() {
            if let Ok(v) = wire::roundtrip(stream, req) {
                return Ok(v);
            }
            *guard = None;
        }
        let mut fresh = Self::dial(&self.addr, timeout)?;
        let v = wire::roundtrip(&mut fresh, req)?;
        *guard = Some(fresh);
        Ok(v)
    }
}

/// Router counters (surfaced through `status`/`metrics`).
#[derive(Default)]
struct RouterCounters {
    routed: AtomicU64,
    shed: AtomicU64,
    errors: AtomicU64,
    failovers: AtomicU64,
    replays: AtomicU64,
}

struct RouterHandler {
    cfg: RouterConfig,
    workers: Vec<WorkerLink>,
    /// Immutable after bootstrap; per-dataset state behind its own lock.
    datasets: BTreeMap<String, Mutex<DatasetState>>,
    inflight: AtomicUsize,
    started: Instant,
    counters: RouterCounters,
    shared: Arc<ListenerShared>,
}

/// The router process's front-end. Client-facing API mirrors
/// [`super::WireServer`]: bind, serve, drop to shut down.
pub struct ShardRouter {
    listener: WireListener,
    handler: Arc<RouterHandler>,
}

impl ShardRouter {
    /// Bind `listen` and serve the shard fleet at `worker_addrs`.
    /// Bootstraps the dataset/shard universe from the first worker that
    /// answers `status` (retrying up to
    /// [`RouterConfig::bootstrap_timeout`]).
    pub fn bind(worker_addrs: &[String], listen: &str, cfg: RouterConfig) -> Result<ShardRouter> {
        let listener =
            TcpListener::bind(listen).with_context(|| format!("binding {listen}"))?;
        Self::start(worker_addrs, listener, cfg)
    }

    /// Start on an already-bound listener.
    pub fn start(
        worker_addrs: &[String],
        listener: TcpListener,
        cfg: RouterConfig,
    ) -> Result<ShardRouter> {
        if worker_addrs.is_empty() {
            bail!("router needs at least one worker address");
        }
        let workers: Vec<WorkerLink> = worker_addrs
            .iter()
            .map(|addr| WorkerLink {
                addr: addr.clone(),
                conn: Mutex::new(None),
                alive: AtomicBool::new(true),
            })
            .collect();
        let datasets = bootstrap(&workers, &cfg)?;
        let shared = ListenerShared::new(cfg.max_frame);
        let handler = Arc::new(RouterHandler {
            cfg,
            workers,
            datasets,
            inflight: AtomicUsize::new(0),
            started: Instant::now(),
            counters: RouterCounters::default(),
            shared: shared.clone(),
        });
        let listener = WireListener::start(listener, shared, handler.clone())?;
        Ok(ShardRouter { listener, handler })
    }

    /// The bound client-facing address.
    pub fn local_addr(&self) -> std::net::SocketAddr {
        self.listener.local_addr()
    }

    /// Live worker count (a health probe for tests and scripts).
    pub fn workers_live(&self) -> usize {
        self.handler.live_workers().len()
    }

    /// Stop accepting, close connections, join threads.
    pub fn shutdown(self) {
        // Drop order does the work (see WireListener::Drop).
    }
}

/// Learn the dataset/shard universe from the fleet: the first worker to
/// answer `status` defines it (every worker loads identical data — the
/// cuts and epochs are deterministic, see module docs). Workers are
/// assumed epoch-aligned at boot; one that diverged will fail its first
/// `apply_delta` with an epoch gap and be marked dead.
fn bootstrap(
    workers: &[WorkerLink],
    cfg: &RouterConfig,
) -> Result<BTreeMap<String, Mutex<DatasetState>>> {
    let deadline = Instant::now() + cfg.bootstrap_timeout;
    let status = loop {
        let mut last_err = None;
        let mut answered = None;
        for w in workers {
            match w.call(&WireRequest::Status { id: 0 }, cfg.worker_timeout) {
                Ok(v) if wire::response_status(&v) == "ok" => {
                    answered = Some(v);
                    break;
                }
                Ok(v) => {
                    last_err = Some(anyhow::anyhow!(
                        "worker {} status answered {:?}",
                        w.addr,
                        wire::response_status(&v)
                    ))
                }
                Err(e) => last_err = Some(e),
            }
        }
        if let Some(v) = answered {
            break v;
        }
        if Instant::now() >= deadline {
            return Err(last_err
                .unwrap_or_else(|| anyhow::anyhow!("no worker answered status"))
                .context("router bootstrap timed out"));
        }
        std::thread::sleep(Duration::from_millis(100));
    };
    let mut datasets = BTreeMap::new();
    for d in status.get("datasets").context("worker status: missing datasets")?.as_arr()? {
        let name = d.get("name").context("status dataset: missing name")?.as_str()?.to_string();
        let nodes = d.get("nodes").context("status dataset: missing nodes")?.as_usize()?;
        let classes =
            d.get("classes").context("status dataset: missing classes")?.as_usize()?;
        let epoch = d.get("epoch").context("status dataset: missing epoch")?.as_f64()? as u64;
        let mut bounds = Vec::new();
        for b in d
            .get("shard_bounds")
            .context("status dataset: missing shard_bounds (worker predates shard serving?)")?
            .as_arr()?
        {
            let pair = b.as_arr()?;
            if pair.len() != 2 {
                bail!("status dataset {name}: malformed shard bound");
            }
            bounds.push((pair[0].as_usize()?, pair[1].as_usize()?));
        }
        if bounds.is_empty() {
            bounds.push((0, nodes));
        }
        let placement = (0..bounds.len()).map(|i| i % workers.len()).collect();
        datasets.insert(
            name,
            Mutex::new(DatasetState {
                nodes,
                classes,
                bounds,
                placement,
                head: epoch,
                watermarks: vec![epoch; workers.len()],
                log: Vec::new(),
            }),
        );
    }
    if datasets.is_empty() {
        bail!("worker fleet serves no datasets");
    }
    Ok(datasets)
}

impl FrameHandler for RouterHandler {
    fn handle(&self, body: &[u8]) -> JsonValue {
        let text = match std::str::from_utf8(body) {
            Ok(t) => t,
            Err(_) => return wire::error_response(0, "frame is not UTF-8"),
        };
        let doc = match crate::util::parse_json(text) {
            Ok(d) => d,
            Err(e) => return wire::error_response(0, &format!("frame is not JSON: {e:#}")),
        };
        let req = match WireRequest::from_json(&doc) {
            Ok(r) => r,
            Err(e) => {
                return wire::error_response(wire::request_id(&doc), &format!("{e:#}"))
            }
        };
        match req {
            WireRequest::Logits { id, route } => self.route_logits(id, route),
            WireRequest::Infer { id, route, nodes } => self.route_infer(id, route, nodes),
            WireRequest::Mutate { id, dataset, ops } => self.route_mutate(id, &dataset, &ops),
            WireRequest::Status { id } => self.status(id),
            WireRequest::Metrics { id } => self.metrics(id),
            WireRequest::Routes { id } => {
                wire::ok_response(id, vec![("routes", JsonValue::Arr(Vec::new()))])
            }
            WireRequest::ShardInfer { id, .. }
            | WireRequest::ShardLogits { id, .. }
            | WireRequest::ApplyDelta { id, .. } => wire::error_response(
                id,
                "shard-plane requests address workers, not the router",
            ),
        }
    }
}

/// RAII in-flight slot (same shape as the front-end's admission gate).
struct Admission<'a>(&'a AtomicUsize);

impl Drop for Admission<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::AcqRel);
    }
}

fn num(x: u64) -> JsonValue {
    JsonValue::Num(x as f64)
}

impl RouterHandler {
    fn admit(&self) -> Option<Admission<'_>> {
        let prev = self.inflight.fetch_add(1, Ordering::AcqRel);
        if prev >= self.cfg.high_water {
            self.inflight.fetch_sub(1, Ordering::AcqRel);
            self.counters.shed.fetch_add(1, Ordering::Relaxed);
            return None;
        }
        Some(Admission(&self.inflight))
    }

    fn live_workers(&self) -> Vec<usize> {
        (0..self.workers.len())
            .filter(|&i| self.workers[i].alive.load(Ordering::Acquire))
            .collect()
    }

    /// Mark a worker dead (idempotent; counts a failover once).
    fn mark_dead(&self, widx: usize) {
        if self.workers[widx].alive.swap(false, Ordering::AcqRel) {
            self.counters.failovers.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Replay log entries above `widx`'s watermark, in order. Holds the
    /// dataset lock (caller-provided `st`) across the worker I/O —
    /// replication is serialized per dataset by design.
    fn catch_up(&self, dataset: &str, st: &mut DatasetState, widx: usize) -> Result<()> {
        let from = st.watermarks[widx];
        for entry in &st.log {
            if entry.epoch <= from {
                continue;
            }
            let req = WireRequest::ApplyDelta {
                id: 0,
                dataset: dataset.to_string(),
                ops: entry.ops.clone(),
                epoch: entry.epoch,
            };
            let resp = self.workers[widx].call(&req, self.cfg.worker_timeout)?;
            if wire::response_status(&resp) != "ok" {
                bail!(
                    "worker {} refused replayed epoch {}: {}",
                    self.workers[widx].addr,
                    entry.epoch,
                    resp.get("error").ok().and_then(|e| e.as_str().ok()).unwrap_or("?")
                );
            }
            st.watermarks[widx] = entry.epoch;
            self.counters.replays.fetch_add(1, Ordering::Relaxed);
        }
        Ok(())
    }

    /// Re-place dead workers' shards onto survivors and catch the
    /// inheritors up to `head`. Loops because a survivor can die during
    /// its own catch-up; bounded by the worker count.
    fn heal_placement(&self, dataset: &str, st: &mut DatasetState) -> Result<()> {
        loop {
            let live = self.live_workers();
            if live.is_empty() {
                bail!("no live shard workers (all {} failed)", self.workers.len());
            }
            let mut moved = 0usize;
            for p in st.placement.iter_mut() {
                if !self.workers[*p].alive.load(Ordering::Acquire) {
                    *p = live[moved % live.len()];
                    moved += 1;
                }
            }
            let mut placed: Vec<usize> = st.placement.clone();
            placed.sort_unstable();
            placed.dedup();
            let mut healthy = true;
            for widx in placed {
                if st.watermarks[widx] < st.head && self.catch_up(dataset, st, widx).is_err() {
                    self.mark_dead(widx);
                    healthy = false;
                    break;
                }
            }
            if healthy {
                return Ok(());
            }
        }
    }

    /// Snapshot a dataset's routing state, healing placement first.
    fn snapshot(
        &self,
        dataset: &str,
    ) -> Result<(u64, Vec<(usize, usize)>, Vec<usize>, usize, usize)> {
        let st = self
            .datasets
            .get(dataset)
            .with_context(|| format!("router serves no dataset {dataset:?}"))?;
        let mut st = st.lock().unwrap();
        self.heal_placement(dataset, &mut st)?;
        Ok((st.head, st.bounds.clone(), st.placement.clone(), st.classes, st.nodes))
    }

    /// A worker served an epoch below the router head: replay it up and
    /// let the caller retry. An epoch *above* head means something
    /// mutated a worker behind the router's back — fatal for ordering,
    /// so the worker is dropped from the fleet.
    fn reconcile_epoch(&self, dataset: &str, widx: usize, served: u64, head: u64) {
        if served < head {
            if let Some(st) = self.datasets.get(dataset) {
                let mut st = st.lock().unwrap();
                st.watermarks[widx] = st.watermarks[widx].min(served);
                if self.catch_up(dataset, &mut st, widx).is_err() {
                    self.mark_dead(widx);
                }
            }
        } else {
            self.mark_dead(widx);
        }
    }

    /// Scatter `shard_logits` over the placement, gather the row
    /// slices, and merge by concatenation in row order. Retries after
    /// healing on worker failure or epoch lag; two healing rounds is
    /// enough for any single failure plus one racing death.
    fn route_logits(&self, id: u64, route: RouteKey) -> JsonValue {
        let Some(_slot) = self.admit() else {
            return wire::shed_response(id, "router in-flight high-water mark reached");
        };
        self.counters.routed.fetch_add(1, Ordering::Relaxed);
        let mut last_err = String::new();
        for _attempt in 0..3 {
            let (head, bounds, placement, classes, nodes) = match self.snapshot(&route.dataset)
            {
                Ok(s) => s,
                Err(e) => return self.fail(id, &format!("{e:#}")),
            };
            let mut bits: Vec<JsonValue> = Vec::with_capacity(nodes * classes);
            let mut ok = true;
            for (shard, &(row_start, row_end)) in bounds.iter().enumerate() {
                let widx = placement[shard];
                let req = WireRequest::ShardLogits {
                    id,
                    route: route.clone(),
                    row_start,
                    row_end,
                };
                let resp = match self.workers[widx].call(&req, self.cfg.worker_timeout) {
                    Ok(r) => r,
                    Err(e) => {
                        last_err = format!("worker {}: {e:#}", self.workers[widx].addr);
                        self.mark_dead(widx);
                        ok = false;
                        break;
                    }
                };
                match wire::response_status(&resp) {
                    "ok" => {}
                    "shed" => return wire::shed_response(id, "shard worker shed the slice"),
                    _ => {
                        return self.fail(
                            id,
                            resp.get("error")
                                .ok()
                                .and_then(|e| e.as_str().ok())
                                .unwrap_or("shard worker error"),
                        )
                    }
                }
                let served =
                    resp.get("epoch").ok().and_then(|e| e.as_f64().ok()).unwrap_or(0.0) as u64;
                if served != head {
                    last_err = format!(
                        "worker {} served epoch {served}, router head {head}",
                        self.workers[widx].addr
                    );
                    self.reconcile_epoch(&route.dataset, widx, served, head);
                    ok = false;
                    break;
                }
                match resp.get("logits_bits").and_then(|b| Ok(b.as_arr()?.to_vec())) {
                    Ok(slice) => bits.extend(slice),
                    Err(e) => return self.fail(id, &format!("shard slice: {e:#}")),
                }
            }
            if ok {
                return wire::ok_response(
                    id,
                    vec![
                        ("rows", num(nodes as u64)),
                        ("classes", num(classes as u64)),
                        ("epoch", num(head)),
                        ("logits_bits", JsonValue::Arr(bits)),
                    ],
                );
            }
        }
        self.fail(id, &format!("scatter failed after failover retries: {last_err}"))
    }

    /// Scatter `infer` nodes to their owning workers, merge predictions
    /// back into request order.
    fn route_infer(&self, id: u64, route: RouteKey, nodes: Vec<usize>) -> JsonValue {
        let Some(_slot) = self.admit() else {
            return wire::shed_response(id, "router in-flight high-water mark reached");
        };
        self.counters.routed.fetch_add(1, Ordering::Relaxed);
        let started = Instant::now();
        let mut last_err = String::new();
        for _attempt in 0..3 {
            let (head, bounds, placement, _classes, n) = match self.snapshot(&route.dataset) {
                Ok(s) => s,
                Err(e) => return self.fail(id, &format!("{e:#}")),
            };
            if let Some(&bad) = nodes.iter().find(|&&node| node >= n) {
                return self.fail(
                    id,
                    &format!("node {bad} out of range (dataset {} has {n} nodes)", route.dataset),
                );
            }
            // Group nodes by owning worker (ownership = the shard whose
            // row range contains the node).
            let mut by_worker: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
            for &node in &nodes {
                let shard = bounds
                    .iter()
                    .position(|&(s, e)| node >= s && node < e)
                    .unwrap_or(bounds.len() - 1);
                by_worker.entry(placement[shard]).or_default().push(node);
            }
            let mut classes_of: BTreeMap<usize, u64> = BTreeMap::new();
            let mut ok = true;
            for (&widx, owned) in &by_worker {
                let req = WireRequest::ShardInfer {
                    id,
                    route: route.clone(),
                    nodes: owned.clone(),
                };
                let resp = match self.workers[widx].call(&req, self.cfg.worker_timeout) {
                    Ok(r) => r,
                    Err(e) => {
                        last_err = format!("worker {}: {e:#}", self.workers[widx].addr);
                        self.mark_dead(widx);
                        ok = false;
                        break;
                    }
                };
                match wire::response_status(&resp) {
                    "ok" => {}
                    "shed" => return wire::shed_response(id, "shard worker shed the batch"),
                    _ => {
                        return self.fail(
                            id,
                            resp.get("error")
                                .ok()
                                .and_then(|e| e.as_str().ok())
                                .unwrap_or("shard worker error"),
                        )
                    }
                }
                let served =
                    resp.get("epoch").ok().and_then(|e| e.as_f64().ok()).unwrap_or(0.0) as u64;
                if served != head {
                    last_err = format!(
                        "worker {} served epoch {served}, router head {head}",
                        self.workers[widx].addr
                    );
                    self.reconcile_epoch(&route.dataset, widx, served, head);
                    ok = false;
                    break;
                }
                let preds = match resp.get("predictions").and_then(|p| Ok(p.as_arr()?.to_vec()))
                {
                    Ok(p) => p,
                    Err(e) => return self.fail(id, &format!("shard predictions: {e:#}")),
                };
                for p in preds {
                    let node =
                        p.get("node").ok().and_then(|x| x.as_usize().ok()).unwrap_or(usize::MAX);
                    let class =
                        p.get("class").ok().and_then(|x| x.as_f64().ok()).unwrap_or(-1.0) as u64;
                    classes_of.insert(node, class);
                }
            }
            if ok {
                let predictions = nodes
                    .iter()
                    .map(|&node| {
                        JsonValue::Obj(
                            [
                                ("node".to_string(), num(node as u64)),
                                (
                                    "class".to_string(),
                                    num(*classes_of.get(&node).unwrap_or(&0)),
                                ),
                            ]
                            .into_iter()
                            .collect(),
                        )
                    })
                    .collect();
                return wire::ok_response(
                    id,
                    vec![
                        ("predictions", JsonValue::Arr(predictions)),
                        ("batch_size", num(by_worker.len() as u64)),
                        ("latency_us", num(started.elapsed().as_micros() as u64)),
                        ("epoch", num(head)),
                    ],
                );
            }
        }
        self.fail(id, &format!("scatter failed after failover retries: {last_err}"))
    }

    /// Broadcast a delta to every live worker as an `apply_delta` log
    /// entry and ack the client only after all live workers acked —
    /// read-your-writes. Holds the dataset lock across the broadcast:
    /// writes to one dataset are serialized, exactly like the
    /// single-process coordinator's delta lock.
    fn route_mutate(&self, id: u64, dataset: &str, ops: &[String]) -> JsonValue {
        let Some(st) = self.datasets.get(dataset) else {
            return self.fail(id, &format!("router serves no dataset {dataset:?}"));
        };
        let mut st = st.lock().unwrap();
        let target = st.head + 1;
        let mut resulting: Option<u64> = None;
        let mut acked = 0usize;
        for widx in 0..self.workers.len() {
            if !self.workers[widx].alive.load(Ordering::Acquire) {
                continue;
            }
            // A lagging live worker must see older entries first, or
            // this entry would open a gap on it.
            if st.watermarks[widx] < st.head && self.catch_up(dataset, &mut st, widx).is_err() {
                self.mark_dead(widx);
                continue;
            }
            let req = WireRequest::ApplyDelta {
                id,
                dataset: dataset.to_string(),
                ops: ops.to_vec(),
                epoch: target,
            };
            match self.workers[widx].call(&req, self.cfg.worker_timeout) {
                Ok(resp) if wire::response_status(&resp) == "ok" => {
                    let e = resp.get("epoch").ok().and_then(|x| x.as_f64().ok()).unwrap_or(0.0)
                        as u64;
                    resulting = Some(resulting.map_or(e, |r| r.max(e)));
                    st.watermarks[widx] = e;
                    acked += 1;
                }
                _ => self.mark_dead(widx),
            }
        }
        let Some(new_head) = resulting else {
            return self.fail(id, "no live worker acked the delta");
        };
        // No-op deltas keep the epoch (the workers' stores decide);
        // only advancing entries join the replay log.
        let advanced = new_head > st.head;
        if advanced {
            st.log.push(LogEntry { epoch: new_head, ops: ops.to_vec() });
            st.head = new_head;
        }
        wire::ok_response(
            id,
            vec![
                ("epoch", num(new_head)),
                ("applied", JsonValue::Bool(advanced)),
                ("workers_acked", num(acked as u64)),
            ],
        )
    }

    fn status(&self, id: u64) -> JsonValue {
        let datasets = self
            .datasets
            .iter()
            .map(|(name, st)| {
                let st = st.lock().unwrap();
                let bounds = st
                    .bounds
                    .iter()
                    .map(|&(s, e)| JsonValue::Arr(vec![num(s as u64), num(e as u64)]))
                    .collect();
                let owners = st.placement.iter().map(|&w| num(w as u64)).collect();
                JsonValue::Obj(
                    [
                        ("name".to_string(), JsonValue::Str(name.clone())),
                        ("nodes".to_string(), num(st.nodes as u64)),
                        ("classes".to_string(), num(st.classes as u64)),
                        ("epoch".to_string(), num(st.head)),
                        ("shard_bounds".to_string(), JsonValue::Arr(bounds)),
                        ("owners".to_string(), JsonValue::Arr(owners)),
                        ("log_entries".to_string(), num(st.log.len() as u64)),
                    ]
                    .into_iter()
                    .collect(),
                )
            })
            .collect();
        let workers = self
            .workers
            .iter()
            .map(|w| {
                JsonValue::Obj(
                    [
                        ("addr".to_string(), JsonValue::Str(w.addr.clone())),
                        (
                            "alive".to_string(),
                            JsonValue::Bool(w.alive.load(Ordering::Acquire)),
                        ),
                    ]
                    .into_iter()
                    .collect(),
                )
            })
            .collect();
        wire::ok_response(
            id,
            vec![
                ("role", JsonValue::Str("router".to_string())),
                ("uptime_us", num(self.started.elapsed().as_micros() as u64)),
                ("datasets", JsonValue::Arr(datasets)),
                ("workers", num(self.live_workers().len() as u64)),
                ("workers_total", num(self.workers.len() as u64)),
                ("worker_fleet", JsonValue::Arr(workers)),
                ("inflight", num(self.inflight.load(Ordering::Acquire) as u64)),
                ("high_water", num(self.cfg.high_water as u64)),
                ("failovers", num(self.counters.failovers.load(Ordering::Relaxed))),
                ("replays", num(self.counters.replays.load(Ordering::Relaxed))),
                ("connections", num(self.shared.open_connections() as u64)),
                ("accept_errors", num(self.shared.accept_errors())),
            ],
        )
    }

    fn metrics(&self, id: u64) -> JsonValue {
        wire::ok_response(
            id,
            vec![
                ("routed", num(self.counters.routed.load(Ordering::Relaxed))),
                ("shed", num(self.counters.shed.load(Ordering::Relaxed))),
                ("errors", num(self.counters.errors.load(Ordering::Relaxed))),
                ("failovers", num(self.counters.failovers.load(Ordering::Relaxed))),
                ("replays", num(self.counters.replays.load(Ordering::Relaxed))),
                ("workers_live", num(self.live_workers().len() as u64)),
            ],
        )
    }

    fn fail(&self, id: u64, msg: &str) -> JsonValue {
        self.counters.errors.fetch_add(1, Ordering::Relaxed);
        wire::error_response(id, msg)
    }
}
