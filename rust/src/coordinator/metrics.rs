//! Lock-cheap service metrics: counters + sub-bucketed latency
//! histograms with per-route quantile tracking.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Values below this many µs get unit-width buckets (exact to 1µs).
const LINEAR_MAX: u64 = 16;
/// Linear sub-buckets per power-of-two octave above the linear range.
const SUB_BUCKETS: usize = 16;
/// log2(LINEAR_MAX): the first sub-bucketed octave.
const FIRST_OCTAVE: usize = 4;
/// Octaves 2^4..2^40 µs — the top covers ~12 days, far past any sane
/// request latency; larger values clamp into the last bucket.
const OCTAVES: usize = 36;
const NUM_BUCKETS: usize = LINEAR_MAX as usize + OCTAVES * SUB_BUCKETS;

/// Lock-free duration histogram: unit-width buckets up to 16µs, then
/// 16 linear sub-buckets per power-of-two octave, so every quantile
/// estimate carries at most 1/16 ≈ 6% relative error — tight enough to
/// gate p999 in CI, unlike plain log2 buckets whose upper bound can be
/// 2× the true value. Recording is a handful of relaxed atomic adds.
#[derive(Debug)]
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

/// Bucket holding a value of `us` microseconds.
fn bucket_index(us: u64) -> usize {
    if us < LINEAR_MAX {
        return us as usize;
    }
    let octave = 63 - us.leading_zeros() as usize;
    if octave >= FIRST_OCTAVE + OCTAVES {
        return NUM_BUCKETS - 1;
    }
    let sub = ((us >> (octave - FIRST_OCTAVE)) & (SUB_BUCKETS as u64 - 1)) as usize;
    LINEAR_MAX as usize + (octave - FIRST_OCTAVE) * SUB_BUCKETS + sub
}

/// Exclusive upper bound (µs) of bucket `idx` — the quantile estimate.
fn bucket_upper_us(idx: usize) -> u64 {
    if idx < LINEAR_MAX as usize {
        return idx as u64 + 1;
    }
    let k = idx - LINEAR_MAX as usize;
    let octave = FIRST_OCTAVE + k / SUB_BUCKETS;
    let sub = (k % SUB_BUCKETS) as u64;
    (SUB_BUCKETS as u64 + sub + 1) << (octave - FIRST_OCTAVE)
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self {
            buckets: (0..NUM_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }

    pub fn record(&self, d: Duration) {
        let us = d.as_micros() as u64;
        self.buckets[bucket_index(us)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> Duration {
        let c = self.count();
        if c == 0 {
            return Duration::ZERO;
        }
        Duration::from_micros(self.sum_us.load(Ordering::Relaxed) / c)
    }

    pub fn max(&self) -> Duration {
        Duration::from_micros(self.max_us.load(Ordering::Relaxed))
    }

    /// Upper-bound estimate of percentile `p` (nearest-rank over the
    /// bucket counts). `p` is in percent: `percentile(99.9)` is p999.
    pub fn percentile(&self, p: f64) -> Duration {
        let total = self.count();
        if total == 0 {
            return Duration::ZERO;
        }
        let target = (((p / 100.0) * total as f64).ceil() as u64).max(1);
        let mut acc = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            acc += b.load(Ordering::Relaxed);
            if acc >= target {
                return Duration::from_micros(bucket_upper_us(i));
            }
        }
        self.max()
    }
}

/// Per-route latency digest inside a [`MetricsSnapshot`].
#[derive(Clone, Debug)]
pub struct RouteLatencySnapshot {
    /// Requests answered on this route.
    pub requests: u64,
    pub p50: Duration,
    pub p99: Duration,
    pub p999: Duration,
    pub mean: Duration,
}

/// Service-wide metrics.
#[derive(Debug, Default)]
pub struct Metrics {
    pub submitted: AtomicU64,
    pub rejected: AtomicU64,
    pub completed: AtomicU64,
    pub failed: AtomicU64,
    pub batches: AtomicU64,
    /// Executions served from the route plan cache (no disk load on the
    /// critical path — including plans a prefetch staged just in time).
    pub plan_hits: AtomicU64,
    /// Route plan builds, wherever they ran: inline on a batch worker or
    /// ahead of time on the prefetch pool.
    pub plan_misses: AtomicU64,
    /// Batches executed through a sharded plan (per-shard sampling +
    /// dispatch, row-concatenated merge).
    pub sharded_batches: AtomicU64,
    /// Graph epochs advanced by `apply_delta` (changing deltas only).
    pub graph_epochs: AtomicU64,
    /// Shard units a delta invalidated (re-sampled on next use).
    pub shards_resampled: AtomicU64,
    /// Shard units a delta re-tagged to the new epoch without
    /// rebuilding (the scoped-invalidation win — untouched shards).
    pub shards_retained: AtomicU64,
    /// Wire requests refused by admission control — the in-flight
    /// high-water mark or a full intake queue — and answered with an
    /// explicit `shed` response, never silently dropped
    /// (docs/serving.md).
    pub shed: AtomicU64,
    pub latency: Histogram,
    pub queue_wait: Histogram,
    pub exec_time: Histogram,
    pub load_time: Histogram,
    /// Per-route execution (batch) counts.
    per_route: Mutex<BTreeMap<String, u64>>,
    /// Per-route end-to-end request latency histograms. The map lock
    /// guards only the route→histogram binding; recording itself is
    /// lock-free on the shared [`Histogram`].
    route_latency: Mutex<BTreeMap<String, Arc<Histogram>>>,
}

/// Point-in-time snapshot for reporting.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub submitted: u64,
    pub rejected: u64,
    pub completed: u64,
    pub failed: u64,
    pub batches: u64,
    pub plan_hits: u64,
    pub plan_misses: u64,
    pub sharded_batches: u64,
    pub graph_epochs: u64,
    pub shards_resampled: u64,
    pub shards_retained: u64,
    pub shed: u64,
    pub latency_p50: Duration,
    pub latency_p99: Duration,
    pub latency_p999: Duration,
    pub latency_mean: Duration,
    pub queue_wait_p50: Duration,
    pub exec_p50: Duration,
    pub load_p50: Duration,
    /// Per-route execution (batch) counts.
    pub per_route: BTreeMap<String, u64>,
    /// Per-route request latency quantiles.
    pub route_latency: BTreeMap<String, RouteLatencySnapshot>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_route(&self, label: &str) {
        *self.per_route.lock().unwrap().entry(label.to_string()).or_insert(0) += 1;
    }

    /// Record one request's end-to-end latency against its route.
    pub fn record_route_latency(&self, label: &str, d: Duration) {
        let hist = {
            let mut map = self.route_latency.lock().unwrap();
            match map.get(label) {
                Some(h) => h.clone(),
                None => {
                    let h = Arc::new(Histogram::new());
                    map.insert(label.to_string(), h.clone());
                    h
                }
            }
        };
        hist.record(d);
    }

    /// Mean requests answered per forward pass (the batching win).
    pub fn amortization(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.completed.load(Ordering::Relaxed) as f64 / b as f64
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let route_latency = self
            .route_latency
            .lock()
            .unwrap()
            .iter()
            .map(|(label, h)| {
                (
                    label.clone(),
                    RouteLatencySnapshot {
                        requests: h.count(),
                        p50: h.percentile(50.0),
                        p99: h.percentile(99.0),
                        p999: h.percentile(99.9),
                        mean: h.mean(),
                    },
                )
            })
            .collect();
        MetricsSnapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            plan_hits: self.plan_hits.load(Ordering::Relaxed),
            plan_misses: self.plan_misses.load(Ordering::Relaxed),
            sharded_batches: self.sharded_batches.load(Ordering::Relaxed),
            graph_epochs: self.graph_epochs.load(Ordering::Relaxed),
            shards_resampled: self.shards_resampled.load(Ordering::Relaxed),
            shards_retained: self.shards_retained.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            latency_p50: self.latency.percentile(50.0),
            latency_p99: self.latency.percentile(99.0),
            latency_p999: self.latency.percentile(99.9),
            latency_mean: self.latency.mean(),
            queue_wait_p50: self.queue_wait.percentile(50.0),
            exec_p50: self.exec_time.percentile(50.0),
            load_p50: self.load_time.percentile(50.0),
            per_route: self.per_route.lock().unwrap().clone(),
            route_latency,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_and_stats() {
        let h = Histogram::new();
        for ms in [1u64, 2, 4, 8, 100] {
            h.record(Duration::from_millis(ms));
        }
        assert_eq!(h.count(), 5);
        assert!(h.max() >= Duration::from_millis(100));
        assert!(h.mean() >= Duration::from_millis(20));
        // p50 upper bound must cover the median value (4ms).
        assert!(h.percentile(50.0) >= Duration::from_millis(4));
        assert!(h.percentile(100.0) >= Duration::from_millis(100));
    }

    #[test]
    fn bucket_layout_is_contiguous_and_monotone() {
        // Every bucket's upper bound equals the next bucket's lower
        // bound: index(upper) == idx + 1 for all but the last bucket.
        for idx in 0..NUM_BUCKETS - 1 {
            let upper = bucket_upper_us(idx);
            assert_eq!(bucket_index(upper), idx + 1, "gap above bucket {idx} ({upper}µs)");
            assert_eq!(bucket_index(upper - 1), idx, "bucket {idx} excludes {upper}-1µs");
        }
        // Clamp: beyond the top octave everything lands in the last bucket.
        assert_eq!(bucket_index(u64::MAX), NUM_BUCKETS - 1);
    }

    #[test]
    fn sub_buckets_bound_quantile_error() {
        // One sample at 1000µs: octave [512, 1024) has 32µs-wide
        // sub-buckets, so the p50 upper bound lands within 32µs — the
        // plain log2 histogram would have reported 1024µs for 513µs.
        let h = Histogram::new();
        h.record(Duration::from_micros(1000));
        let p50 = h.percentile(50.0).as_micros() as u64;
        assert!(p50 > 1000 && p50 <= 1024, "p50 {p50}µs out of sub-bucket range");

        // Exact unit-width buckets below 16µs.
        let h = Histogram::new();
        h.record(Duration::from_micros(7));
        assert_eq!(h.percentile(50.0), Duration::from_micros(8));
    }

    #[test]
    fn p999_on_small_samples_tracks_the_max() {
        // With fewer than 1000 samples the p999 nearest-rank is the
        // last sample: it must land in the max's bucket, never below.
        let h = Histogram::new();
        for us in [100u64, 200, 300, 50_000] {
            h.record(Duration::from_micros(us));
        }
        let p999 = h.percentile(99.9);
        assert!(p999 >= Duration::from_micros(50_000));
        let idx = bucket_index(50_000);
        assert_eq!(p999, Duration::from_micros(bucket_upper_us(idx)));
        // And a single sample: p50 == p99 == p999.
        let h = Histogram::new();
        h.record(Duration::from_micros(777));
        assert_eq!(h.percentile(50.0), h.percentile(99.9));
    }

    #[test]
    fn concurrent_recording_loses_nothing() {
        let h = Arc::new(Histogram::new());
        let threads: Vec<_> = (0..8)
            .map(|t| {
                let h = h.clone();
                std::thread::spawn(move || {
                    for i in 0..10_000u64 {
                        h.record(Duration::from_micros(t * 1000 + i % 100));
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(h.count(), 80_000);
        let bucket_total: u64 =
            h.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum();
        assert_eq!(bucket_total, 80_000);
        assert!(h.max() >= Duration::from_micros(7000));
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = Histogram::new();
        assert_eq!(h.percentile(99.0), Duration::ZERO);
        assert_eq!(h.mean(), Duration::ZERO);
    }

    #[test]
    fn amortization() {
        let m = Metrics::new();
        m.completed.store(100, Ordering::Relaxed);
        m.batches.store(10, Ordering::Relaxed);
        assert!((m.amortization() - 10.0).abs() < 1e-9);
    }

    #[test]
    fn per_route_counts() {
        let m = Metrics::new();
        m.record_route("a");
        m.record_route("a");
        m.record_route("b");
        let snap = m.snapshot();
        assert_eq!(snap.per_route["a"], 2);
        assert_eq!(snap.per_route["b"], 1);
    }

    #[test]
    fn per_route_latency_histograms() {
        let m = Metrics::new();
        for _ in 0..10 {
            m.record_route_latency("hot", Duration::from_micros(500));
        }
        m.record_route_latency("hot", Duration::from_millis(80));
        m.record_route_latency("cold", Duration::from_millis(5));
        let snap = m.snapshot();
        let hot = &snap.route_latency["hot"];
        assert_eq!(hot.requests, 11);
        assert!(hot.p50 < Duration::from_millis(1));
        assert!(hot.p999 >= Duration::from_millis(80));
        assert_eq!(snap.route_latency["cold"].requests, 1);
    }
}
