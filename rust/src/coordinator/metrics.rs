//! Lock-cheap service metrics: counters + log-bucketed latency histograms.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Log2-bucketed duration histogram: bucket i covers [2^i, 2^(i+1)) µs.
#[derive(Debug)]
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

const NUM_BUCKETS: usize = 40; // up to ~2^40 µs ≈ 12 days

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self {
            buckets: (0..NUM_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }

    pub fn record(&self, d: Duration) {
        let us = d.as_micros() as u64;
        let idx = (64 - us.max(1).leading_zeros() as usize - 1).min(NUM_BUCKETS - 1);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> Duration {
        let c = self.count();
        if c == 0 {
            return Duration::ZERO;
        }
        Duration::from_micros(self.sum_us.load(Ordering::Relaxed) / c)
    }

    pub fn max(&self) -> Duration {
        Duration::from_micros(self.max_us.load(Ordering::Relaxed))
    }

    /// Upper-bound estimate of percentile `p` from the bucket boundaries.
    pub fn percentile(&self, p: f64) -> Duration {
        let total = self.count();
        if total == 0 {
            return Duration::ZERO;
        }
        let target = ((p / 100.0) * total as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            acc += b.load(Ordering::Relaxed);
            if acc >= target {
                return Duration::from_micros(1u64 << (i + 1));
            }
        }
        self.max()
    }
}

/// Service-wide metrics.
#[derive(Debug, Default)]
pub struct Metrics {
    pub submitted: AtomicU64,
    pub rejected: AtomicU64,
    pub completed: AtomicU64,
    pub failed: AtomicU64,
    pub batches: AtomicU64,
    /// Executions served from the route plan cache (no disk load on the
    /// critical path — including plans a prefetch staged just in time).
    pub plan_hits: AtomicU64,
    /// Route plan builds, wherever they ran: inline on a batch worker or
    /// ahead of time on the prefetch pool.
    pub plan_misses: AtomicU64,
    /// Batches executed through a sharded plan (per-shard sampling +
    /// dispatch, row-concatenated merge).
    pub sharded_batches: AtomicU64,
    /// Graph epochs advanced by `apply_delta` (changing deltas only).
    pub graph_epochs: AtomicU64,
    /// Shard units a delta invalidated (re-sampled on next use).
    pub shards_resampled: AtomicU64,
    /// Shard units a delta re-tagged to the new epoch without
    /// rebuilding (the scoped-invalidation win — untouched shards).
    pub shards_retained: AtomicU64,
    pub latency: Histogram,
    pub queue_wait: Histogram,
    pub exec_time: Histogram,
    pub load_time: Histogram,
    /// Per-route execution counts.
    per_route: Mutex<BTreeMap<String, u64>>,
}

/// Point-in-time snapshot for reporting.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub submitted: u64,
    pub rejected: u64,
    pub completed: u64,
    pub failed: u64,
    pub batches: u64,
    pub plan_hits: u64,
    pub plan_misses: u64,
    pub sharded_batches: u64,
    pub graph_epochs: u64,
    pub shards_resampled: u64,
    pub shards_retained: u64,
    pub latency_p50: Duration,
    pub latency_p99: Duration,
    pub latency_mean: Duration,
    pub queue_wait_p50: Duration,
    pub exec_p50: Duration,
    pub load_p50: Duration,
    pub per_route: BTreeMap<String, u64>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_route(&self, label: &str) {
        *self.per_route.lock().unwrap().entry(label.to_string()).or_insert(0) += 1;
    }

    /// Mean requests answered per forward pass (the batching win).
    pub fn amortization(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.completed.load(Ordering::Relaxed) as f64 / b as f64
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            plan_hits: self.plan_hits.load(Ordering::Relaxed),
            plan_misses: self.plan_misses.load(Ordering::Relaxed),
            sharded_batches: self.sharded_batches.load(Ordering::Relaxed),
            graph_epochs: self.graph_epochs.load(Ordering::Relaxed),
            shards_resampled: self.shards_resampled.load(Ordering::Relaxed),
            shards_retained: self.shards_retained.load(Ordering::Relaxed),
            latency_p50: self.latency.percentile(50.0),
            latency_p99: self.latency.percentile(99.0),
            latency_mean: self.latency.mean(),
            queue_wait_p50: self.queue_wait.percentile(50.0),
            exec_p50: self.exec_time.percentile(50.0),
            load_p50: self.load_time.percentile(50.0),
            per_route: self.per_route.lock().unwrap().clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_and_stats() {
        let h = Histogram::new();
        for ms in [1u64, 2, 4, 8, 100] {
            h.record(Duration::from_millis(ms));
        }
        assert_eq!(h.count(), 5);
        assert!(h.max() >= Duration::from_millis(100));
        assert!(h.mean() >= Duration::from_millis(20));
        // p50 upper bound must cover the median value (4ms).
        assert!(h.percentile(50.0) >= Duration::from_millis(4));
        assert!(h.percentile(100.0) >= Duration::from_millis(64));
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = Histogram::new();
        assert_eq!(h.percentile(99.0), Duration::ZERO);
        assert_eq!(h.mean(), Duration::ZERO);
    }

    #[test]
    fn amortization() {
        let m = Metrics::new();
        m.completed.store(100, Ordering::Relaxed);
        m.batches.store(10, Ordering::Relaxed);
        assert!((m.amortization() - 10.0).abs() < 1e-9);
    }

    #[test]
    fn per_route_counts() {
        let m = Metrics::new();
        m.record_route("a");
        m.record_route("a");
        m.record_route("b");
        let snap = m.snapshot();
        assert_eq!(snap.per_route["a"], 2);
        assert_eq!(snap.per_route["b"], 1);
    }
}
