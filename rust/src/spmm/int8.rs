//! True INT8 compute — `i8×u8→i32` accumulating SpMM kernels that run
//! AES-SpMM's Eq. 1/2 *in the quantized domain* instead of
//! dequantizing features to fp32 first.
//!
//! # The math
//!
//! With features stored as u8 codes `q[c,k]` under per-row-chunk ranges
//! (Eq. 2: `x̂[c,k] = q[c,k]·s(c) + m(c)`, `s = span/255`, `m = x_min`),
//! the aggregation row is
//!
//! ```text
//! C[i,k] = Σ_e v_e · x̂[c_e,k]
//!        = Σ_e (v_e·s(c_e)) · q[c_e,k]  +  Σ_e v_e·m(c_e)
//! ```
//!
//! The fp32 edge coefficients `a_e = v_e·s(c_e)` (the per-chunk rescale,
//! folded in at build time) are re-quantized **per row** with a
//! symmetric 7-bit scheme: `a_e ≈ qa_e · row_scale_i`. That turns the
//! first sum into a pure integer MAC loop, with exactly one rescale at
//! the end of the row:
//!
//! ```text
//! C[i,k] ≈ row_scale_i · (Σ_e qa_e · q[c_e,k])_i32 + row_base_i
//! ```
//!
//! # Overflow and determinism
//!
//! `|qa·q| ≤ 127·255 = 32 385`, so an i32 accumulator is exact for up to
//! ~66 k edges; rows longer than [`I8_FLUSH_EDGES`] flush into an f32
//! partial at fixed, row-local boundaries. Integer accumulation is
//! associative and the flush boundaries depend only on the row's edge
//! count, so every dispatch arm, thread count, and shard cut produces
//! bitwise-identical output — the same composition contract the fp32
//! kernels obey.

use crate::graph::{Csr, Ell};
use crate::quant::ChunkedParams;

use super::simd::{self, SimdLevel};
use super::threaded::{balance_rows, split_output};

/// Eq. 1/2's code range (255 levels), as f32.
const LEVELS: f32 = 255.0;

/// Symmetric 7-bit target for the per-row edge-coefficient requant.
const QA_MAX: f32 = 127.0;

/// Edges per exact-i32 segment: `2^31 / 32 385 ≈ 66 296`; 32 768 leaves
/// 2x headroom. Boundaries are row-local, so sharding and threading
/// (which cut between rows) can never move them.
pub const I8_FLUSH_EDGES: usize = 32_768;

/// Per-row requantized adjacency — the integer-domain operand the
/// [`ell_spmm_i8`] / [`csr_spmm_i8`] kernels consume. Built once per
/// plan (it depends only on the adjacency and the feature chunk
/// ranges), reused across batches.
#[derive(Clone, Debug)]
pub struct AdjQuant {
    /// `row_scale[i]`: the symmetric step `max_e |a_e| / 127` (1.0 for
    /// empty/all-zero rows).
    pub row_scale: Vec<f32>,
    /// `row_base[i] = Σ_e v_e · x_min(chunk(c_e))` — the k-independent
    /// offset added to every output column of row `i`.
    pub row_base: Vec<f32>,
    /// Quantized edge coefficients in the source layout (ELL:
    /// `n_rows × width` including zeroed padding slots; CSR: nnz order).
    pub qa: Vec<i8>,
}

impl AdjQuant {
    /// Requantize a sampled (ELL) adjacency against the feature matrix's
    /// chunk ranges. `params` must cover `ell.n_cols` feature rows.
    pub fn from_ell(ell: &Ell, params: &ChunkedParams) -> AdjQuant {
        assert!(
            params.n_rows() >= ell.n_cols,
            "chunk params cover {} rows, ELL references {}",
            params.n_rows(),
            ell.n_cols
        );
        let w = ell.width;
        let mut aq = AdjQuant {
            row_scale: vec![1.0; ell.n_rows],
            row_base: vec![0.0; ell.n_rows],
            qa: vec![0i8; ell.n_rows * w],
        };
        let mut coeff = vec![0.0f32; w];
        for i in 0..ell.n_rows {
            let n = ell.slots[i] as usize;
            let vals = &ell.val[i * w..i * w + n];
            let cols = &ell.col[i * w..i * w + n];
            let (scale, base) =
                quantize_row(vals, cols, params, &mut coeff[..n], &mut aq.qa[i * w..i * w + n]);
            aq.row_scale[i] = scale;
            aq.row_base[i] = base;
        }
        aq
    }

    /// Requantize an exact (CSR) adjacency against the feature matrix's
    /// chunk ranges. `params` must cover `csr.n_cols` feature rows.
    pub fn from_csr(csr: &Csr, params: &ChunkedParams) -> AdjQuant {
        assert!(
            params.n_rows() >= csr.n_cols,
            "chunk params cover {} rows, CSR references {}",
            params.n_rows(),
            csr.n_cols
        );
        let nnz = csr.val.len();
        let mut aq = AdjQuant {
            row_scale: vec![1.0; csr.n_rows],
            row_base: vec![0.0; csr.n_rows],
            qa: vec![0i8; nnz],
        };
        let mut coeff = Vec::new();
        for i in 0..csr.n_rows {
            let r = csr.row_range(i);
            coeff.resize(r.len(), 0.0);
            let (scale, base) = quantize_row(
                &csr.val[r.clone()],
                &csr.col_ind[r.clone()],
                params,
                &mut coeff,
                &mut aq.qa[r],
            );
            aq.row_scale[i] = scale;
            aq.row_base[i] = base;
        }
        aq
    }
}

/// Fold the per-chunk rescale into fp32 edge coefficients, then
/// symmetric-quantize them to i8. Returns `(row_scale, row_base)`.
fn quantize_row(
    vals: &[f32],
    cols: &[i32],
    params: &ChunkedParams,
    coeff: &mut [f32],
    qa: &mut [i8],
) -> (f32, f32) {
    let mut base = 0.0f32;
    let mut amax = 0.0f32;
    for ((a, v), &c) in coeff.iter_mut().zip(vals.iter()).zip(cols.iter()) {
        let p = params.for_row(c as usize);
        *a = v * (p.scale() / LEVELS);
        base += v * p.x_min;
        amax = amax.max(a.abs());
    }
    let scale = if amax == 0.0 { 1.0 } else { amax / QA_MAX };
    for (q, a) in qa.iter_mut().zip(coeff.iter()) {
        *q = (a / scale).round().clamp(-QA_MAX, QA_MAX) as i8;
    }
    (scale, base)
}

/// Sampled (ELL) SpMM in the quantized domain:
/// `out[i,k] = row_scale[i] · Σ_e qa[i,e] · qb[col[i,e], k] + row_base[i]`.
///
/// `qb` is the row-major `[n_cols, f]` u8 feature codes — typically a
/// zero-copy borrow of the memory-mapped `featq` payload, so no fp32
/// feature block ever materializes.
pub fn ell_spmm_i8(ell: &Ell, aq: &AdjQuant, qb: &[u8], f: usize, out: &mut [f32]) {
    ell_spmm_i8_at(simd::level(), ell, aq, qb, f, out)
}

/// [`ell_spmm_i8`] pinned to an explicit SIMD level (tests/benches).
pub fn ell_spmm_i8_at(
    lvl: SimdLevel,
    ell: &Ell,
    aq: &AdjQuant,
    qb: &[u8],
    f: usize,
    out: &mut [f32],
) {
    assert_eq!(qb.len(), ell.n_cols * f);
    assert_eq!(out.len(), ell.n_rows * f);
    assert_eq!(aq.qa.len(), ell.n_rows * ell.width);
    ell_spmm_i8_rows(lvl, ell, aq, qb, f, 0..ell.n_rows, out);
}

/// Row-range worker shared by the serial entry and the threaded wrapper.
fn ell_spmm_i8_rows(
    lvl: SimdLevel,
    ell: &Ell,
    aq: &AdjQuant,
    qb: &[u8],
    f: usize,
    rows: std::ops::Range<usize>,
    out: &mut [f32],
) {
    let w = ell.width;
    let mut acc = vec![0i32; f];
    for (oi, i) in rows.enumerate() {
        simd::prefetch_read(&aq.qa, (i + 1) * w);
        simd::prefetch_read(&ell.col, (i + 1) * w);
        let n = ell.slots[i] as usize;
        i8_row_rescale(
            lvl,
            &aq.qa[i * w..i * w + n],
            &ell.col[i * w..i * w + n],
            qb,
            f,
            aq.row_scale[i],
            aq.row_base[i],
            &mut acc,
            &mut out[oi * f..(oi + 1) * f],
        );
    }
}

/// Exact (CSR) SpMM in the quantized domain — same contract as
/// [`ell_spmm_i8`] with `aq.qa` in nnz order.
pub fn csr_spmm_i8(csr: &Csr, aq: &AdjQuant, qb: &[u8], f: usize, out: &mut [f32]) {
    csr_spmm_i8_at(simd::level(), csr, aq, qb, f, out)
}

/// [`csr_spmm_i8`] pinned to an explicit SIMD level (tests/benches).
pub fn csr_spmm_i8_at(
    lvl: SimdLevel,
    csr: &Csr,
    aq: &AdjQuant,
    qb: &[u8],
    f: usize,
    out: &mut [f32],
) {
    assert_eq!(qb.len(), csr.n_cols * f);
    assert_eq!(out.len(), csr.n_rows * f);
    assert_eq!(aq.qa.len(), csr.val.len());
    csr_spmm_i8_rows(lvl, csr, aq, qb, f, 0..csr.n_rows, out);
}

fn csr_spmm_i8_rows(
    lvl: SimdLevel,
    csr: &Csr,
    aq: &AdjQuant,
    qb: &[u8],
    f: usize,
    rows: std::ops::Range<usize>,
    out: &mut [f32],
) {
    let mut acc = vec![0i32; f];
    for (oi, i) in rows.enumerate() {
        let r = csr.row_range(i);
        i8_row_rescale(
            lvl,
            &aq.qa[r.clone()],
            &csr.col_ind[r],
            qb,
            f,
            aq.row_scale[i],
            aq.row_base[i],
            &mut acc,
            &mut out[oi * f..(oi + 1) * f],
        );
    }
}

/// Parallel [`ell_spmm_i8`] — row chunks on the shared exec pool, same
/// per-row worker as the serial kernel (bitwise-identical).
pub fn ell_spmm_i8_par(
    ell: &Ell,
    aq: &AdjQuant,
    qb: &[u8],
    f: usize,
    out: &mut [f32],
    threads: usize,
) {
    assert_eq!(qb.len(), ell.n_cols * f);
    assert_eq!(out.len(), ell.n_rows * f);
    assert_eq!(aq.qa.len(), ell.n_rows * ell.width);
    let lvl = simd::level();
    let chunks = balance_rows(|i| ell.slots[i] as usize, ell.n_rows, threads.max(1));
    let slices = split_output(out, &chunks, f);
    let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = chunks
        .into_iter()
        .zip(slices)
        .map(|(range, slice)| {
            Box::new(move || {
                ell_spmm_i8_rows(lvl, ell, aq, qb, f, range, slice);
            }) as Box<dyn FnOnce() + Send + '_>
        })
        .collect();
    crate::exec::global_pool().run(tasks);
}

/// Parallel [`csr_spmm_i8`].
pub fn csr_spmm_i8_par(
    csr: &Csr,
    aq: &AdjQuant,
    qb: &[u8],
    f: usize,
    out: &mut [f32],
    threads: usize,
) {
    assert_eq!(qb.len(), csr.n_cols * f);
    assert_eq!(out.len(), csr.n_rows * f);
    assert_eq!(aq.qa.len(), csr.val.len());
    let lvl = simd::level();
    let chunks = balance_rows(|i| csr.row_nnz(i), csr.n_rows, threads.max(1));
    let slices = split_output(out, &chunks, f);
    let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = chunks
        .into_iter()
        .zip(slices)
        .map(|(range, slice)| {
            Box::new(move || {
                csr_spmm_i8_rows(lvl, csr, aq, qb, f, range, slice);
            }) as Box<dyn FnOnce() + Send + '_>
        })
        .collect();
    crate::exec::global_pool().run(tasks);
}

/// One output row: integer-accumulate `Σ_e qa_e · qb[c_e,·]` in
/// [`I8_FLUSH_EDGES`]-long exact segments, then apply the single
/// per-row rescale `out = scale·acc + base`. Shared with the format
/// zoo's i8 kernels (`formats.rs`) so every layout keeps the identical
/// row-local flush boundaries.
#[allow(clippy::too_many_arguments)]
pub(crate) fn i8_row_rescale(
    lvl: SimdLevel,
    qa: &[i8],
    cols: &[i32],
    qb: &[u8],
    f: usize,
    scale: f32,
    base: f32,
    acc: &mut [i32],
    row_out: &mut [f32],
) {
    let n = qa.len();
    if n <= I8_FLUSH_EDGES {
        acc.fill(0);
        i8_row(lvl, qa, cols, qb, f, acc);
        for (o, &a) in row_out.iter_mut().zip(acc.iter()) {
            *o = scale * a as f32 + base;
        }
    } else {
        row_out.fill(0.0);
        let mut lo = 0usize;
        while lo < n {
            let hi = (lo + I8_FLUSH_EDGES).min(n);
            acc.fill(0);
            i8_row(lvl, &qa[lo..hi], &cols[lo..hi], qb, f, acc);
            for (o, &a) in row_out.iter_mut().zip(acc.iter()) {
                *o += a as f32;
            }
            lo = hi;
        }
        for o in row_out.iter_mut() {
            *o = scale * *o + base;
        }
    }
}

/// The integer MAC inner loop: `acc[k] += qa[e] · qb[cols[e]·f + k]`.
/// Exact in every arm (i32 adds commute), so dispatch is bitwise-free.
#[inline]
fn i8_row(lvl: SimdLevel, qa: &[i8], cols: &[i32], qb: &[u8], f: usize, acc: &mut [i32]) {
    debug_assert_eq!(qa.len(), cols.len());
    debug_assert_eq!(acc.len(), f);
    match lvl {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `simd::level()` only reports Avx2 after runtime detection.
        SimdLevel::Avx2 => unsafe { i8_row_avx2(qa, cols, qb, f, acc) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: `simd::level()` only reports Neon after runtime detection.
        SimdLevel::Neon => unsafe { i8_row_neon(qa, cols, qb, f, acc) },
        _ => i8_row_scalar(qa, cols, qb, f, acc),
    }
}

fn i8_row_scalar(qa: &[i8], cols: &[i32], qb: &[u8], f: usize, acc: &mut [i32]) {
    for (q, &c) in qa.iter().zip(cols.iter()) {
        let a = *q as i32;
        // Padding slots and rounded-to-zero coefficients contribute
        // nothing; skipping them is exact.
        if a == 0 {
            continue;
        }
        let qrow = &qb[c as usize * f..c as usize * f + f];
        for (s, &x) in acc.iter_mut().zip(qrow.iter()) {
            *s += a * x as i32;
        }
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn i8_row_avx2(qa: &[i8], cols: &[i32], qb: &[u8], f: usize, acc: &mut [i32]) {
    use core::arch::x86_64::*;
    for (q, &c) in qa.iter().zip(cols.iter()) {
        let a = *q as i32;
        if a == 0 {
            continue;
        }
        let av = _mm256_set1_epi32(a);
        let base = qb.as_ptr().add(c as usize * f);
        let mut k = 0usize;
        while k + 8 <= f {
            // 8 u8 codes → 8 i32 lanes, 32-bit multiply, accumulate.
            // (Not maddubs: that saturates at i16 and folds lane pairs.)
            let x8 = _mm_loadl_epi64(base.add(k) as *const __m128i);
            let x = _mm256_cvtepu8_epi32(x8);
            let prod = _mm256_mullo_epi32(av, x);
            let prev = _mm256_loadu_si256(acc.as_ptr().add(k) as *const __m256i);
            _mm256_storeu_si256(
                acc.as_mut_ptr().add(k) as *mut __m256i,
                _mm256_add_epi32(prev, prod),
            );
            k += 8;
        }
        while k < f {
            *acc.get_unchecked_mut(k) += a * *base.add(k) as i32;
            k += 1;
        }
    }
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn i8_row_neon(qa: &[i8], cols: &[i32], qb: &[u8], f: usize, acc: &mut [i32]) {
    use core::arch::aarch64::*;
    for (q, &c) in qa.iter().zip(cols.iter()) {
        let a = *q as i32;
        if a == 0 {
            continue;
        }
        let a16 = vdup_n_s16(a as i16);
        let base = qb.as_ptr().add(c as usize * f);
        let mut k = 0usize;
        while k + 8 <= f {
            // 8 u8 codes widened to s16 (≤ 255 fits), then a widening
            // multiply-accumulate into the s32 lanes: |q·x| ≤ 32 385.
            let x16 = vreinterpretq_s16_u16(vmovl_u8(vld1_u8(base.add(k))));
            let acc0 = vld1q_s32(acc.as_ptr().add(k));
            let acc1 = vld1q_s32(acc.as_ptr().add(k + 4));
            vst1q_s32(acc.as_mut_ptr().add(k), vmlal_s16(acc0, vget_low_s16(x16), a16));
            vst1q_s32(acc.as_mut_ptr().add(k + 4), vmlal_s16(acc1, vget_high_s16(x16), a16));
            k += 8;
        }
        while k < f {
            *acc.get_unchecked_mut(k) += a * *base.add(k) as i32;
            k += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::QuantParams;
    use crate::sampling::{sample_ell, Strategy};
    use crate::spmm::testutil::random_graph_and_features;
    use crate::spmm::{csr_naive, ell_spmm};

    /// Quantize features with per-chunk ranges and return
    /// `(codes, params, dequantized fp32 view)`.
    fn quantized_features(
        b: &[f32],
        n: usize,
        f: usize,
        rows_per_chunk: usize,
    ) -> (Vec<u8>, ChunkedParams, Vec<f32>) {
        let params = ChunkedParams::of_rows(b, n, f, rows_per_chunk);
        let qb = params.quantize_rows(b, f);
        let mut deq = vec![0.0f32; qb.len()];
        params.dequantize_rows_into(&qb, 0, f, &mut deq);
        (qb, params, deq)
    }

    /// Per-element bound on the i8-compute vs dequant-reference gap:
    /// only the qa rounding differs, so |err| ≤ ½·row_scale·Σ_e q[c_e,k]
    /// plus fp32 accumulation noise.
    fn assert_within_requant_bound(
        got: &[f32],
        want: &[f32],
        aq: &AdjQuant,
        row_edge_codesum: impl Fn(usize, usize) -> f32,
        f: usize,
    ) {
        for (idx, (g, w)) in got.iter().zip(want.iter()).enumerate() {
            let (i, k) = (idx / f, idx % f);
            let bound = 0.5 * aq.row_scale[i] * row_edge_codesum(i, k)
                + 1e-4 * (1.0 + w.abs());
            assert!(
                (g - w).abs() <= bound,
                "row {i} col {k}: {g} vs {w} (bound {bound})"
            );
        }
    }

    #[test]
    fn ell_i8_tracks_dequant_reference_with_chunked_scales() {
        let (n, f, width) = (180usize, 24usize, 12usize);
        let (g, b) = random_graph_and_features(n, 14.0, f, 31);
        // 5 chunks of very different magnitude (seeded features are
        // uniform, so scale rows to force distinct per-chunk ranges).
        let mut scaled = b.clone();
        for (i, x) in scaled.iter_mut().enumerate() {
            *x *= 1.0 + (i / (f * 40)) as f32 * 3.0;
        }
        let (qb, params, deq) = quantized_features(&scaled, n, f, 40);
        assert!(params.n_chunks() > 1);
        let ell = sample_ell(&g, width, Strategy::Aes);
        let aq = AdjQuant::from_ell(&ell, &params);

        // Reference: dequantize-then-fp32 over the same sampled plan.
        let mut want = vec![0.0f32; n * f];
        ell_spmm(&ell, &deq, f, &mut want);
        let mut got = vec![0.0f32; n * f];
        ell_spmm_i8(&ell, &aq, &qb, f, &mut got);

        let w = ell.width;
        assert_within_requant_bound(
            &got,
            &want,
            &aq,
            |i, k| {
                let nsl = ell.slots[i] as usize;
                (0..nsl)
                    .map(|e| qb[ell.col[i * w + e] as usize * f + k] as f32)
                    .sum()
            },
            f,
        );
    }

    #[test]
    fn csr_i8_tracks_dequant_reference() {
        let (n, f) = (150usize, 17usize);
        let (g, b) = random_graph_and_features(n, 9.0, f, 57);
        let (qb, params, deq) = quantized_features(&b, n, f, 50);
        let aq = AdjQuant::from_csr(&g, &params);
        let mut want = vec![0.0f32; n * f];
        csr_naive(&g, &deq, f, &mut want);
        let mut got = vec![0.0f32; n * f];
        csr_spmm_i8(&g, &aq, &qb, f, &mut got);
        assert_within_requant_bound(
            &got,
            &want,
            &aq,
            |i, k| {
                g.row_range(i)
                    .map(|e| qb[g.col_ind[e] as usize * f + k] as f32)
                    .sum()
            },
            f,
        );
    }

    #[test]
    fn i8_simd_matches_scalar_bitwise() {
        for f in [1usize, 7, 8, 9, 33] {
            let (g, b) = random_graph_and_features(90, 11.0, f, 77 + f as u64);
            let (qb, params, _) = quantized_features(&b, 90, f, 16);
            let ell = sample_ell(&g, 8, Strategy::Aes);
            let aq = AdjQuant::from_ell(&ell, &params);
            let mut scalar = vec![0.0f32; 90 * f];
            let mut vector = vec![0.0f32; 90 * f];
            ell_spmm_i8_at(SimdLevel::Scalar, &ell, &aq, &qb, f, &mut scalar);
            ell_spmm_i8_at(simd::level(), &ell, &aq, &qb, f, &mut vector);
            assert_eq!(scalar, vector, "f={f}");
        }
    }

    #[test]
    fn i8_par_matches_serial_bitwise() {
        let (n, f) = (300usize, 13usize);
        let (g, b) = random_graph_and_features(n, 20.0, f, 5);
        let (qb, params, _) = quantized_features(&b, n, f, 64);
        let ell = sample_ell(&g, 16, Strategy::Aes);
        let aq = AdjQuant::from_ell(&ell, &params);
        let mut serial = vec![0.0f32; n * f];
        ell_spmm_i8(&ell, &aq, &qb, f, &mut serial);
        for threads in [2usize, 3, 8] {
            let mut par = vec![0.0f32; n * f];
            ell_spmm_i8_par(&ell, &aq, &qb, f, &mut par, threads);
            assert_eq!(serial, par, "threads={threads}");
        }
        let caq = AdjQuant::from_csr(&g, &params);
        let mut cs = vec![0.0f32; n * f];
        csr_spmm_i8(&g, &caq, &qb, f, &mut cs);
        let mut cp = vec![0.0f32; n * f];
        csr_spmm_i8_par(&g, &caq, &qb, f, &mut cp, 4);
        assert_eq!(cs, cp);
    }

    #[test]
    fn empty_rows_yield_their_base_term() {
        // A graph with an isolated row: scale defaults to 1, base to 0,
        // so the output row is exactly zero.
        let g = crate::graph::Csr::new(3, 3, vec![0, 1, 1, 2], vec![2, 0], vec![0.5, -2.0])
            .unwrap();
        let b = vec![0.25f32; 6];
        let params = ChunkedParams::uniform(3, QuantParams { x_min: 0.0, x_max: 1.0 });
        let qb = params.quantize_rows(&b, 2);
        let aq = AdjQuant::from_csr(&g, &params);
        let mut out = vec![9.0f32; 6];
        csr_spmm_i8(&g, &aq, &qb, 2, &mut out);
        assert_eq!(&out[2..4], &[0.0, 0.0]);
        // Non-empty rows land near v · 0.25.
        assert!((out[0] - 0.125).abs() < 0.01, "{}", out[0]);
        assert!((out[4] + 0.5).abs() < 0.02, "{}", out[4]);
    }

    #[test]
    fn flush_segmentation_is_exactly_additive() {
        // A row longer than the flush segment still matches the direct
        // integer sum (values chosen so all partials are exact in f32).
        let n_edges = I8_FLUSH_EDGES + 10;
        let qa = vec![1i8; n_edges];
        let cols = vec![0i32; n_edges];
        let qb = vec![1u8; 4];
        let mut acc = vec![0i32; 4];
        let mut row = vec![0.0f32; 4];
        i8_row_rescale(simd::level(), &qa, &cols, &qb, 4, 1.0, 0.0, &mut acc, &mut row);
        // Σ over edges of 1·1, accumulated in two segments.
        assert_eq!(row, vec![n_edges as f32; 4]);
    }
}
