//! Runtime-dispatched SIMD layer for the SpMM hot loops.
//!
//! # Detection and dispatch contract
//!
//! [`level`] probes the CPU once per process (AVX2 via
//! `is_x86_feature_detected!`, NEON via the aarch64 equivalent) and
//! caches the answer; exporting `AES_SPMM_FORCE_SCALAR=1` before first
//! use pins the scalar arm (the CI matrix runs the whole suite that
//! way). Kernels dispatch at row or tile granularity, so the `match`
//! cost amortizes over `edges × f` of inner work and the
//! `#[target_feature]` bodies inline their intrinsics fully.
//!
//! # Why dispatch never changes a bit
//!
//! Vector lanes map to *independent output feature columns*; each
//! column accumulates over edges in the kernel's canonical order, and
//! multiply/add stay separate instructions (no FMA — rustc never
//! contracts scalar `a + b * c` either). Per output element every arm
//! performs the identical ordered sequence of fp32 operations, so the
//! scalar path is not a fallback with different numerics: it is the
//! *same* numerics, and the eval oracle's bitwise guarantees hold under
//! any dispatch decision (docs/simd.md).
//!
//! # Cache model (the shared-memory-fit analog)
//!
//! The paper sizes sampled tiles so the multiply fits GPU shared
//! memory. On CPU, [`cache_profile`] reads L1d/LLC sizes from
//! `/sys/devices/system/cpu/cpu0/cache` (fallbacks 32 KiB / 8 MiB),
//! [`edge_tile`] sizes the rowcache staging tile from the L1d budget,
//! and [`feat_block`] sizes feature-column passes so the touched B rows
//! stay LLC-resident.

use std::sync::OnceLock;

/// Environment variable that pins dispatch to the scalar arm when set
/// to `1` (read once, before the first kernel call).
pub const FORCE_SCALAR_ENV: &str = "AES_SPMM_FORCE_SCALAR";

/// The instruction-set arm a kernel call executes with.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdLevel {
    /// Portable scalar loops — the canonical FP order.
    Scalar,
    /// x86-64 AVX2 (8 × f32 / 8 × i32 lanes).
    Avx2,
    /// aarch64 NEON (dual 4 × f32 / 4 × i32 lanes, blocked to 8).
    Neon,
}

impl SimdLevel {
    /// Stable label for logs and bench case names.
    pub fn name(self) -> &'static str {
        match self {
            SimdLevel::Scalar => "scalar",
            SimdLevel::Avx2 => "avx2",
            SimdLevel::Neon => "neon",
        }
    }
}

/// The process-wide detected dispatch level (cached after first call).
pub fn level() -> SimdLevel {
    static LEVEL: OnceLock<SimdLevel> = OnceLock::new();
    *LEVEL.get_or_init(detect)
}

fn detect() -> SimdLevel {
    if std::env::var(FORCE_SCALAR_ENV).map(|v| v == "1").unwrap_or(false) {
        return SimdLevel::Scalar;
    }
    #[cfg(target_arch = "x86_64")]
    if is_x86_feature_detected!("avx2") {
        return SimdLevel::Avx2;
    }
    #[cfg(target_arch = "aarch64")]
    if std::arch::is_aarch64_feature_detected!("neon") {
        return SimdLevel::Neon;
    }
    SimdLevel::Scalar
}

/// Detected cache sizes used to tune tile shapes. Tuning only moves
/// *performance* knobs (tile lengths, block widths); it never changes
/// which FP operations run per output element.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CacheProfile {
    /// Per-core L1 data cache in bytes.
    pub l1d_bytes: usize,
    /// Last-level cache in bytes (the largest Data/Unified level seen).
    pub llc_bytes: usize,
}

/// L1d assumed when sysfs is absent (containers, non-Linux).
pub const L1D_FALLBACK_BYTES: usize = 32 * 1024;
/// LLC assumed when sysfs is absent.
pub const LLC_FALLBACK_BYTES: usize = 8 * 1024 * 1024;

/// The machine's cache profile (detected once, sysfs or fallbacks).
pub fn cache_profile() -> CacheProfile {
    static PROFILE: OnceLock<CacheProfile> = OnceLock::new();
    *PROFILE.get_or_init(|| detect_caches("/sys/devices/system/cpu/cpu0/cache"))
}

/// Parse a sysfs cache size string like `32K`, `1024K` or `8M`.
fn parse_cache_size(s: &str) -> Option<usize> {
    let t = s.trim();
    let (digits, mult) = match t.as_bytes().last()? {
        b'K' | b'k' => (&t[..t.len() - 1], 1024),
        b'M' | b'm' => (&t[..t.len() - 1], 1024 * 1024),
        _ => (t, 1),
    };
    digits.parse::<usize>().ok().map(|n| n.saturating_mul(mult))
}

fn detect_caches(base: &str) -> CacheProfile {
    let mut l1d = None;
    // (level, bytes) of the deepest Data/Unified cache seen so far.
    let mut llc: Option<(u32, usize)> = None;
    for idx in 0..16 {
        let dir = format!("{base}/index{idx}");
        let Ok(ty) = std::fs::read_to_string(format!("{dir}/type")) else {
            break;
        };
        let (Ok(level_s), Ok(size_s)) = (
            std::fs::read_to_string(format!("{dir}/level")),
            std::fs::read_to_string(format!("{dir}/size")),
        ) else {
            continue;
        };
        let Ok(lv) = level_s.trim().parse::<u32>() else {
            continue;
        };
        let Some(bytes) = parse_cache_size(&size_s) else {
            continue;
        };
        let ty = ty.trim();
        if ty == "Instruction" {
            continue;
        }
        if lv == 1 && ty == "Data" {
            l1d = Some(bytes);
        }
        if llc.map_or(true, |(deepest, _)| lv >= deepest) {
            llc = Some((lv, bytes));
        }
    }
    CacheProfile {
        l1d_bytes: l1d.unwrap_or(L1D_FALLBACK_BYTES),
        llc_bytes: llc.map(|(_, b)| b).unwrap_or(LLC_FALLBACK_BYTES),
    }
}

/// Bytes one staged edge occupies in the rowcache tile: an `f32` value
/// plus a `usize` column index.
const STAGED_EDGE_BYTES: usize = std::mem::size_of::<f32>() + std::mem::size_of::<usize>();

/// Floor of the tuned staging tile — equal to
/// [`crate::spmm::ROWCACHE_TILE`], the dispatch gate's row-size cap, so
/// a dispatched row always fits one tile and accumulates in plain edge
/// order on every machine (the bitwise contract is tile-size-proof).
pub const EDGE_TILE_MIN: usize = 256;
/// Staging past this stops paying: the tile would spill L1 anyway.
pub const EDGE_TILE_MAX: usize = 4096;

/// Rowcache staging-tile length, tuned to a quarter of the detected L1d
/// (the rest stays available for the feature rows streaming through).
pub fn edge_tile() -> usize {
    static TILE: OnceLock<usize> = OnceLock::new();
    *TILE.get_or_init(|| {
        (cache_profile().l1d_bytes / 4 / STAGED_EDGE_BYTES).clamp(EDGE_TILE_MIN, EDGE_TILE_MAX)
    })
}

/// Feature-column block width for LLC tiling: the widest multiple of 8
/// such that one pass's working set (`n_b_rows` feature rows of the
/// block) fits half the LLC; `f` itself when everything fits. The
/// paper's shared-memory-fit argument, restated for the cache that
/// actually bounds CPU SpMM.
pub fn feat_block(n_b_rows: usize, f: usize) -> usize {
    let budget = cache_profile().llc_bytes / 2;
    let per_col = n_b_rows.max(1) * std::mem::size_of::<f32>();
    let cols = budget / per_col;
    if cols >= f {
        f
    } else {
        (cols & !7).max(8)
    }
}

/// Best-effort prefetch of `data[idx..]` into L1 (x86-64 only: the
/// aarch64 `prfm` intrinsic is unstable and hardware stride prefetchers
/// already cover the sequential ELL walk there). No-op out of bounds.
#[inline(always)]
pub fn prefetch_read<T>(data: &[T], idx: usize) {
    #[cfg(target_arch = "x86_64")]
    if idx < data.len() {
        // SAFETY: the pointer is in bounds and prefetch has no
        // architectural effect — it can neither fault nor write.
        unsafe {
            use core::arch::x86_64::{_mm_prefetch, _MM_HINT_T0};
            _mm_prefetch::<_MM_HINT_T0>(data.as_ptr().add(idx) as *const i8);
        }
    }
    #[cfg(not(target_arch = "x86_64"))]
    let _ = (data, idx);
}

/// One sampled (ELL) or staged row over feature columns
/// `k0 .. k0 + out.len()`:
/// `out[j] += Σ_e vals[e] * b[cols[e] * f + k0 + j]`.
///
/// `out` is the row's column sub-slice; `cols` entries must index valid
/// `b` rows. Bitwise-identical across levels: per output element every
/// arm runs the same ordered load–mul–add sequence.
#[inline]
pub fn ell_row(
    lvl: SimdLevel,
    vals: &[f32],
    cols: &[i32],
    b: &[f32],
    f: usize,
    k0: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(vals.len(), cols.len());
    debug_assert!(k0 + out.len() <= f);
    match lvl {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `level()` only reports Avx2 after runtime detection.
        SimdLevel::Avx2 => unsafe { ell_row_avx2(vals, cols, b, f, k0, out) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: `level()` only reports Neon after runtime detection.
        SimdLevel::Neon => unsafe { ell_row_neon(vals, cols, b, f, k0, out) },
        _ => ell_row_scalar(vals, cols, b, f, k0, out),
    }
}

fn ell_row_scalar(vals: &[f32], cols: &[i32], b: &[f32], f: usize, k0: usize, out: &mut [f32]) {
    for (v, &c) in vals.iter().zip(cols.iter()) {
        let lo = c as usize * f + k0;
        let brow = &b[lo..lo + out.len()];
        for (o, &x) in out.iter_mut().zip(brow.iter()) {
            *o += *v * x;
        }
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn ell_row_avx2(vals: &[f32], cols: &[i32], b: &[f32], f: usize, k0: usize, out: &mut [f32]) {
    use core::arch::x86_64::*;
    let width = out.len();
    let mut k = 0usize;
    while k + 8 <= width {
        // Start from the current output block so the per-lane operation
        // sequence is exactly the scalar one (out += v1*x1 += v2*x2 …).
        let mut acc = _mm256_loadu_ps(out.as_ptr().add(k));
        for (v, &c) in vals.iter().zip(cols.iter()) {
            let x = _mm256_loadu_ps(b.as_ptr().add(c as usize * f + k0 + k));
            // mul then add, kept separate: no FMA contraction.
            acc = _mm256_add_ps(acc, _mm256_mul_ps(_mm256_set1_ps(*v), x));
        }
        _mm256_storeu_ps(out.as_mut_ptr().add(k), acc);
        k += 8;
    }
    while k < width {
        let mut acc = *out.get_unchecked(k);
        for (v, &c) in vals.iter().zip(cols.iter()) {
            acc += *v * *b.get_unchecked(c as usize * f + k0 + k);
        }
        *out.get_unchecked_mut(k) = acc;
        k += 1;
    }
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn ell_row_neon(vals: &[f32], cols: &[i32], b: &[f32], f: usize, k0: usize, out: &mut [f32]) {
    use core::arch::aarch64::*;
    let width = out.len();
    let mut k = 0usize;
    while k + 8 <= width {
        let mut acc0 = vld1q_f32(out.as_ptr().add(k));
        let mut acc1 = vld1q_f32(out.as_ptr().add(k + 4));
        for (v, &c) in vals.iter().zip(cols.iter()) {
            let base = b.as_ptr().add(c as usize * f + k0 + k);
            let vv = vdupq_n_f32(*v);
            // vmul + vadd (never vfma): scalar parity.
            acc0 = vaddq_f32(acc0, vmulq_f32(vv, vld1q_f32(base)));
            acc1 = vaddq_f32(acc1, vmulq_f32(vv, vld1q_f32(base.add(4))));
        }
        vst1q_f32(out.as_mut_ptr().add(k), acc0);
        vst1q_f32(out.as_mut_ptr().add(k + 4), acc1);
        k += 8;
    }
    while k < width {
        let mut acc = *out.get_unchecked(k);
        for (v, &c) in vals.iter().zip(cols.iter()) {
            acc += *v * *b.get_unchecked(c as usize * f + k0 + k);
        }
        *out.get_unchecked_mut(k) = acc;
        k += 1;
    }
}

/// One staged rowcache tile (CWM analog):
/// `out[k] += Σ_t tile_val[t] * b[tile_col[t] * f + k]`, each 8-column
/// block accumulated in registers before touching `out`, exactly like
/// the scalar reference order.
#[inline]
pub fn tile_axpy(
    lvl: SimdLevel,
    tile_val: &[f32],
    tile_col: &[usize],
    b: &[f32],
    f: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(tile_val.len(), tile_col.len());
    debug_assert_eq!(out.len(), f);
    match lvl {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `level()` only reports Avx2 after runtime detection.
        SimdLevel::Avx2 => unsafe { tile_axpy_avx2(tile_val, tile_col, b, f, out) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: `level()` only reports Neon after runtime detection.
        SimdLevel::Neon => unsafe { tile_axpy_neon(tile_val, tile_col, b, f, out) },
        _ => tile_axpy_scalar(tile_val, tile_col, b, f, out),
    }
}

fn tile_axpy_scalar(tile_val: &[f32], tile_col: &[usize], b: &[f32], f: usize, out: &mut [f32]) {
    let mut k = 0usize;
    while k + 8 <= f {
        let mut acc = [0.0f32; 8];
        for (v, &c) in tile_val.iter().zip(tile_col.iter()) {
            let brow = &b[c * f + k..c * f + k + 8];
            for (a, &x) in acc.iter_mut().zip(brow.iter()) {
                *a += *v * x;
            }
        }
        for (o, a) in out[k..k + 8].iter_mut().zip(acc.iter()) {
            *o += a;
        }
        k += 8;
    }
    while k < f {
        let mut acc = 0.0f32;
        for (v, &c) in tile_val.iter().zip(tile_col.iter()) {
            acc += *v * b[c * f + k];
        }
        out[k] += acc;
        k += 1;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn tile_axpy_avx2(tile_val: &[f32], tile_col: &[usize], b: &[f32], f: usize, out: &mut [f32]) {
    use core::arch::x86_64::*;
    let mut k = 0usize;
    while k + 8 <= f {
        let mut acc = _mm256_setzero_ps();
        for (v, &c) in tile_val.iter().zip(tile_col.iter()) {
            let x = _mm256_loadu_ps(b.as_ptr().add(c * f + k));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(_mm256_set1_ps(*v), x));
        }
        let prev = _mm256_loadu_ps(out.as_ptr().add(k));
        _mm256_storeu_ps(out.as_mut_ptr().add(k), _mm256_add_ps(prev, acc));
        k += 8;
    }
    while k < f {
        let mut acc = 0.0f32;
        for (v, &c) in tile_val.iter().zip(tile_col.iter()) {
            acc += *v * *b.get_unchecked(c * f + k);
        }
        *out.get_unchecked_mut(k) += acc;
        k += 1;
    }
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn tile_axpy_neon(tile_val: &[f32], tile_col: &[usize], b: &[f32], f: usize, out: &mut [f32]) {
    use core::arch::aarch64::*;
    let mut k = 0usize;
    while k + 8 <= f {
        let mut acc0 = vdupq_n_f32(0.0);
        let mut acc1 = vdupq_n_f32(0.0);
        for (v, &c) in tile_val.iter().zip(tile_col.iter()) {
            let base = b.as_ptr().add(c * f + k);
            let vv = vdupq_n_f32(*v);
            acc0 = vaddq_f32(acc0, vmulq_f32(vv, vld1q_f32(base)));
            acc1 = vaddq_f32(acc1, vmulq_f32(vv, vld1q_f32(base.add(4))));
        }
        let prev0 = vld1q_f32(out.as_ptr().add(k));
        let prev1 = vld1q_f32(out.as_ptr().add(k + 4));
        vst1q_f32(out.as_mut_ptr().add(k), vaddq_f32(prev0, acc0));
        vst1q_f32(out.as_mut_ptr().add(k + 4), vaddq_f32(prev1, acc1));
        k += 8;
    }
    while k < f {
        let mut acc = 0.0f32;
        for (v, &c) in tile_val.iter().zip(tile_col.iter()) {
            acc += *v * *b.get_unchecked(c * f + k);
        }
        *out.get_unchecked_mut(k) += acc;
        k += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;

    fn rand_case(n_b: usize, edges: usize, f: usize, seed: u64) -> (Vec<f32>, Vec<i32>, Vec<f32>) {
        let mut rng = Pcg32::new(seed);
        let vals: Vec<f32> = (0..edges).map(|_| rng.f32() - 0.5).collect();
        let cols: Vec<i32> = (0..edges).map(|_| rng.usize_below(n_b) as i32).collect();
        let b: Vec<f32> = (0..n_b * f).map(|_| rng.f32() - 0.5).collect();
        (vals, cols, b)
    }

    #[test]
    fn detected_level_matches_scalar_bitwise_ell_row() {
        // Remainder lanes on purpose: below, at, and off the 8-lane width.
        for f in [1usize, 3, 7, 8, 9, 16, 33, 64] {
            let (vals, cols, b) = rand_case(40, 90, f, 7 + f as u64);
            let mut scalar = vec![0.1f32; f];
            let mut vector = vec![0.1f32; f];
            ell_row(SimdLevel::Scalar, &vals, &cols, &b, f, 0, &mut scalar);
            ell_row(level(), &vals, &cols, &b, f, 0, &mut vector);
            assert_eq!(scalar, vector, "f={f} lvl={}", level().name());
        }
    }

    #[test]
    fn detected_level_matches_scalar_bitwise_tile_axpy() {
        for f in [1usize, 5, 8, 11, 24, 31] {
            let (vals, cols, b) = rand_case(30, 70, f, 19 + f as u64);
            let ucols: Vec<usize> = cols.iter().map(|&c| c as usize).collect();
            let mut scalar = vec![0.2f32; f];
            let mut vector = vec![0.2f32; f];
            tile_axpy(SimdLevel::Scalar, &vals, &ucols, &b, f, &mut scalar);
            tile_axpy(level(), &vals, &ucols, &b, f, &mut vector);
            assert_eq!(scalar, vector, "f={f}");
        }
    }

    #[test]
    fn empty_edge_list_is_identity() {
        let b = vec![1.0f32; 8];
        let mut out = vec![3.5f32; 8];
        ell_row(level(), &[], &[], &b, 8, 0, &mut out);
        assert_eq!(out, vec![3.5f32; 8]);
        tile_axpy(level(), &[], &[], &b, 8, &mut out);
        assert_eq!(out, vec![3.5f32; 8]);
    }

    #[test]
    fn column_offset_addresses_the_right_block() {
        let f = 12usize;
        let (vals, cols, b) = rand_case(10, 25, f, 3);
        let mut full = vec![0.0f32; f];
        ell_row(level(), &vals, &cols, &b, f, 0, &mut full);
        // Same row computed in two blocked passes must agree bitwise.
        let mut blocked = vec![0.0f32; f];
        ell_row(level(), &vals, &cols, &b, f, 0, &mut blocked[..5]);
        ell_row(level(), &vals, &cols, &b, f, 5, &mut blocked[5..]);
        assert_eq!(full, blocked);
    }

    #[test]
    fn cache_size_parsing() {
        assert_eq!(parse_cache_size("32K\n"), Some(32 * 1024));
        assert_eq!(parse_cache_size("8192K"), Some(8192 * 1024));
        assert_eq!(parse_cache_size("12M"), Some(12 * 1024 * 1024));
        assert_eq!(parse_cache_size("65536"), Some(65536));
        assert_eq!(parse_cache_size("weird"), None);
    }

    #[test]
    fn missing_sysfs_falls_back() {
        let p = detect_caches("/definitely/not/a/sysfs/path");
        assert_eq!(p.l1d_bytes, L1D_FALLBACK_BYTES);
        assert_eq!(p.llc_bytes, LLC_FALLBACK_BYTES);
    }

    #[test]
    fn tile_and_block_bounds() {
        let t = edge_tile();
        assert!((EDGE_TILE_MIN..=EDGE_TILE_MAX).contains(&t));
        // The dispatch gate's cap always fits one tile.
        assert!(t >= crate::spmm::ROWCACHE_TILE);
        // feat_block: multiples of 8 under pressure, f when it fits.
        assert_eq!(feat_block(16, 64), 64);
        let under_pressure = feat_block(usize::MAX / 8, 640);
        assert_eq!(under_pressure, 8);
        let mid = feat_block(LLC_FALLBACK_BYTES, 1 << 20);
        assert_eq!(mid % 8, 0);
    }

    #[test]
    fn prefetch_is_safe_at_any_index() {
        let data = [1u8, 2, 3];
        prefetch_read(&data, 0);
        prefetch_read(&data, 2);
        prefetch_read(&data, 3); // out of bounds: must be a no-op
        prefetch_read::<u8>(&[], 0);
    }
}
