//! Sampled (ELL) SpMM — Algorithm 1 lines 16–19 on the host: multiply the
//! fixed-width sampled matrix against dense features. Padding slots hold
//! (0.0, col 0), so no masking is needed in the inner loop.

use crate::graph::Ell;
use crate::spmm::simd::{self, SimdLevel};

/// `C[i,:] = Σ_k ell.val[i,k] * B[ell.col[i,k],:]` (GCN aggregation),
/// dispatched at the detected SIMD level.
pub fn ell_spmm(ell: &Ell, b: &[f32], f: usize, out: &mut [f32]) {
    ell_spmm_at(simd::level(), ell, b, f, out)
}

/// [`ell_spmm`] pinned to an explicit SIMD level — the bitwise
/// cross-checks in tests and the scalar-vs-SIMD bench cases use this;
/// serving code should call [`ell_spmm`].
pub fn ell_spmm_at(lvl: SimdLevel, ell: &Ell, b: &[f32], f: usize, out: &mut [f32]) {
    assert_eq!(b.len(), ell.n_cols * f);
    assert_eq!(out.len(), ell.n_rows * f);
    out.fill(0.0);
    ell_spmm_rows(lvl, ell, b, f, 0..ell.n_rows, out);
}

/// Row-range worker shared by the serial entry and the threaded
/// wrapper: computes rows `rows` into the chunk-local `out`
/// (`rows.len() * f`, pre-zeroed by the caller).
///
/// Feature columns are processed in LLC-sized blocks
/// ([`simd::feat_block`]) so the B rows a pass touches stay
/// cache-resident — the paper's shared-memory-fit argument. Blocking
/// only reorders *independent* output elements; per element the edge
/// accumulation order is unchanged, so the result is bitwise-identical
/// to the unblocked scalar loop at every level.
pub(crate) fn ell_spmm_rows(
    lvl: SimdLevel,
    ell: &Ell,
    b: &[f32],
    f: usize,
    rows: std::ops::Range<usize>,
    out: &mut [f32],
) {
    let w = ell.width;
    let blk = simd::feat_block(ell.n_cols, f);
    let mut k0 = 0usize;
    while k0 < f {
        let k1 = (k0 + blk).min(f);
        for (oi, i) in rows.clone().enumerate() {
            // Pull the next row's staged (col, val) segment into cache
            // while this row computes.
            simd::prefetch_read(&ell.val, (i + 1) * w);
            simd::prefetch_read(&ell.col, (i + 1) * w);
            let n = ell.slots[i] as usize;
            let vals = &ell.val[i * w..i * w + n];
            let cols = &ell.col[i * w..i * w + n];
            simd::ell_row(lvl, vals, cols, b, f, k0, &mut out[oi * f + k0..oi * f + k1]);
        }
        k0 = k1;
    }
}

/// Mean variant: divide each row by its valid slot count (GraphSAGE).
pub fn ell_spmm_mean(ell: &Ell, b: &[f32], f: usize, out: &mut [f32]) {
    ell_spmm(ell, b, f, out);
    for i in 0..ell.n_rows {
        let d = ell.slots[i].max(1) as f32;
        for o in &mut out[i * f..(i + 1) * f] {
            *o /= d;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampling::{sample_ell, Strategy};
    use crate::spmm::csr_naive;
    use crate::spmm::testutil::{assert_close, random_graph_and_features};

    #[test]
    fn full_width_sampling_equals_exact() {
        let (g, b) = random_graph_and_features(200, 10.0, 9, 4);
        let wmax = g.max_degree();
        for strat in Strategy::ALL {
            let ell = sample_ell(&g, wmax, strat);
            let mut a = vec![0.0; g.n_rows * 9];
            let mut c = vec![0.0; g.n_rows * 9];
            csr_naive(&g, &b, 9, &mut a);
            ell_spmm(&ell, &b, 9, &mut c);
            assert_close(&a, &c, 1e-5);
        }
    }

    #[test]
    fn sampled_output_matches_manual_expansion() {
        let (g, b) = random_graph_and_features(100, 40.0, 5, 5);
        let ell = sample_ell(&g, 16, Strategy::Aes);
        let mut out = vec![0.0; g.n_rows * 5];
        ell_spmm(&ell, &b, 5, &mut out);
        // Manual per-slot accumulation.
        let mut want = vec![0.0f32; g.n_rows * 5];
        for i in 0..ell.n_rows {
            for k in 0..ell.slots[i] as usize {
                let v = ell.val[i * 16 + k];
                let c = ell.col[i * 16 + k] as usize;
                for kk in 0..5 {
                    want[i * 5 + kk] += v * b[c * 5 + kk];
                }
            }
        }
        assert_close(&out, &want, 1e-6);
    }

    #[test]
    fn ell_simd_matches_scalar_bitwise() {
        // Remainder lanes, empty rows (width-0 slots), ragged widths.
        for (w, f) in [(4usize, 1usize), (8, 7), (16, 9), (16, 33), (32, 64)] {
            let (g, b) = random_graph_and_features(120, 12.0, f, 21 + f as u64);
            let ell = sample_ell(&g, w, Strategy::Aes);
            let mut scalar = vec![0.0; g.n_rows * f];
            let mut vector = vec![0.0; g.n_rows * f];
            ell_spmm_at(crate::spmm::simd::SimdLevel::Scalar, &ell, &b, f, &mut scalar);
            ell_spmm_at(crate::spmm::simd::level(), &ell, &b, f, &mut vector);
            assert_eq!(scalar, vector, "w={w} f={f}");
        }
    }

    #[test]
    fn mean_divides_by_slots() {
        let (g, b) = random_graph_and_features(80, 30.0, 4, 6);
        let ell = sample_ell(&g, 8, Strategy::Aes);
        let mut sum = vec![0.0; 80 * 4];
        let mut mean = vec![0.0; 80 * 4];
        ell_spmm(&ell, &b, 4, &mut sum);
        ell_spmm_mean(&ell, &b, 4, &mut mean);
        for i in 0..80 {
            let d = ell.slots[i].max(1) as f32;
            for k in 0..4 {
                assert!((mean[i * 4 + k] - sum[i * 4 + k] / d).abs() < 1e-6);
            }
        }
    }
}
