//! Sampled (ELL) SpMM — Algorithm 1 lines 16–19 on the host: multiply the
//! fixed-width sampled matrix against dense features. Padding slots hold
//! (0.0, col 0), so no masking is needed in the inner loop.

use crate::graph::Ell;

/// `C[i,:] = Σ_k ell.val[i,k] * B[ell.col[i,k],:]` (GCN aggregation).
pub fn ell_spmm(ell: &Ell, b: &[f32], f: usize, out: &mut [f32]) {
    assert_eq!(b.len(), ell.n_cols * f);
    assert_eq!(out.len(), ell.n_rows * f);
    out.fill(0.0);
    let w = ell.width;
    for i in 0..ell.n_rows {
        let row_out = &mut out[i * f..(i + 1) * f];
        let vals = &ell.val[i * w..i * w + ell.slots[i] as usize];
        let cols = &ell.col[i * w..i * w + ell.slots[i] as usize];
        for (v, &c) in vals.iter().zip(cols.iter()) {
            let brow = &b[c as usize * f..c as usize * f + f];
            for (o, &x) in row_out.iter_mut().zip(brow.iter()) {
                *o += v * x;
            }
        }
    }
}

/// Mean variant: divide each row by its valid slot count (GraphSAGE).
pub fn ell_spmm_mean(ell: &Ell, b: &[f32], f: usize, out: &mut [f32]) {
    ell_spmm(ell, b, f, out);
    for i in 0..ell.n_rows {
        let d = ell.slots[i].max(1) as f32;
        for o in &mut out[i * f..(i + 1) * f] {
            *o /= d;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampling::{sample_ell, Strategy};
    use crate::spmm::csr_naive;
    use crate::spmm::testutil::{assert_close, random_graph_and_features};

    #[test]
    fn full_width_sampling_equals_exact() {
        let (g, b) = random_graph_and_features(200, 10.0, 9, 4);
        let wmax = g.max_degree();
        for strat in Strategy::ALL {
            let ell = sample_ell(&g, wmax, strat);
            let mut a = vec![0.0; g.n_rows * 9];
            let mut c = vec![0.0; g.n_rows * 9];
            csr_naive(&g, &b, 9, &mut a);
            ell_spmm(&ell, &b, 9, &mut c);
            assert_close(&a, &c, 1e-5);
        }
    }

    #[test]
    fn sampled_output_matches_manual_expansion() {
        let (g, b) = random_graph_and_features(100, 40.0, 5, 5);
        let ell = sample_ell(&g, 16, Strategy::Aes);
        let mut out = vec![0.0; g.n_rows * 5];
        ell_spmm(&ell, &b, 5, &mut out);
        // Manual per-slot accumulation.
        let mut want = vec![0.0f32; g.n_rows * 5];
        for i in 0..ell.n_rows {
            for k in 0..ell.slots[i] as usize {
                let v = ell.val[i * 16 + k];
                let c = ell.col[i * 16 + k] as usize;
                for kk in 0..5 {
                    want[i * 5 + kk] += v * b[c * 5 + kk];
                }
            }
        }
        assert_close(&out, &want, 1e-6);
    }

    #[test]
    fn mean_divides_by_slots() {
        let (g, b) = random_graph_and_features(80, 30.0, 4, 6);
        let ell = sample_ell(&g, 8, Strategy::Aes);
        let mut sum = vec![0.0; 80 * 4];
        let mut mean = vec![0.0; 80 * 4];
        ell_spmm(&ell, &b, 4, &mut sum);
        ell_spmm_mean(&ell, &b, 4, &mut mean);
        for i in 0..80 {
            let d = ell.slots[i].max(1) as f32;
            for k in 0..4 {
                assert!((mean[i * 4 + k] - sum[i * 4 + k] / d).abs() < 1e-6);
            }
        }
    }
}
