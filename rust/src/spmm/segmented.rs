//! Segmented row reductions for attention models — the GAT softmax and
//! the GraphSAGE max-pool, over both CSR (exact) and ELL (sampled)
//! operands, with scalar/AVX2/NEON arms and `_par` row-partitioned
//! variants.
//!
//! # The GAT pipeline
//!
//! GAT turns each layer's aggregation into three segmented passes over
//! the row's edge list (a "segment"):
//!
//! 1. per-edge logits `e = LeakyReLU(s_src[i] + s_dst[col])` in CSR/ELL
//!    storage order, where `s_src = H·a_src`, `s_dst = H·a_dst` are
//!    per-node scores ([`attention_scores`], `k` ascending);
//! 2. a numerically-stable segmented softmax per row
//!    ([`row_softmax`]): subtract the row max, `exp`, normalize by the
//!    storage-order sum;
//! 3. the weighted aggregation itself, which is plain SpMM with α as
//!    edge values — it reuses the existing dispatched kernels, so this
//!    module never re-implements the multiply.
//!
//! On a *sampled* (ELL) operand only the surviving slots enter the
//! softmax, so α renormalizes over the kept edges — the attention
//! analog of the paper's sampled aggregation.
//!
//! # Why dispatch never changes a bit
//!
//! The same contract as [`crate::spmm::simd`], phase by phase:
//!
//! * **max**: an exact selection — every reduction order returns the
//!   same value for finite non-NaN scores, and a `±0.0` sign flip
//!   cannot survive `exp(e − m)` (`x − (+0.0)` and `x − (−0.0)` differ
//!   only in the sign of a zero result, and `exp(±0.0) = 1.0` exactly);
//! * **exp + denominator**: scalar `f32::exp` and a storage-order
//!   scalar sum in *every* arm (fp add is order-sensitive, so no arm
//!   vectorizes it);
//! * **normalize**: per-element IEEE divide, exact in every arm.
//!
//! The max-pool kernels vectorize over feature columns (lanes =
//! independent outputs) and walk edges in storage order in every arm,
//! with the select written as `if x > acc { x } else { acc }` semantics
//! in each instruction set — bitwise parity by construction.

use crate::graph::{Csr, Ell};

use super::simd::SimdLevel;

/// Negative-side slope of the GAT LeakyReLU (the reference value used
/// by the original GAT and by DGL/PyG defaults).
pub const LEAKY_RELU_SLOPE: f32 = 0.2;

/// GAT's LeakyReLU: identity for positive logits, [`LEAKY_RELU_SLOPE`]
/// times the logit otherwise. Written with an explicit branch so
/// `-0.0` falls through the negative side deterministically.
#[inline]
pub fn leaky_relu(e: f32) -> f32 {
    if e > 0.0 {
        e
    } else {
        LEAKY_RELU_SLOPE * e
    }
}

/// Per-node attention scores `s[i] = Σ_k h[i·d + k] · a[k]`, `k`
/// ascending, rows serial — the canonical order shared with the eval
/// oracle.
pub fn attention_scores(h: &[f32], a: &[f32], n: usize, d: usize) -> Vec<f32> {
    assert_eq!(h.len(), n * d, "H is not [n, d]");
    assert_eq!(a.len(), d, "attention vector is not [d]");
    let mut s = vec![0.0f32; n];
    score_rows(h, a, d, 0..n, &mut s);
    s
}

/// Parallel [`attention_scores`] — rows are independent and each keeps
/// the `k`-ascending order, so the result is bitwise equal to serial.
pub fn attention_scores_par(h: &[f32], a: &[f32], n: usize, d: usize, threads: usize) -> Vec<f32> {
    assert_eq!(h.len(), n * d, "H is not [n, d]");
    assert_eq!(a.len(), d, "attention vector is not [d]");
    let mut s = vec![0.0f32; n];
    let parts = threads.max(1).min(n.max(1));
    let chunk = n.div_ceil(parts);
    let mut rest: &mut [f32] = &mut s;
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(parts);
    for part in 0..parts {
        let lo = part * chunk;
        let hi = ((part + 1) * chunk).min(n);
        if lo >= hi {
            break;
        }
        let (out_chunk, r) = rest.split_at_mut(hi - lo);
        rest = r;
        tasks.push(Box::new(move || score_rows(h, a, d, lo..hi, out_chunk)));
    }
    crate::exec::global_pool().run(tasks);
    s
}

fn score_rows(h: &[f32], a: &[f32], d: usize, rows: std::ops::Range<usize>, out: &mut [f32]) {
    let lo = rows.start;
    for i in rows {
        let mut acc = 0.0f32;
        for (x, &w) in h[i * d..(i + 1) * d].iter().zip(a.iter()) {
            acc += *x * w;
        }
        out[i - lo] = acc;
    }
}

/// In-place segmented softmax over one row's contiguous logit slice:
/// subtract the row max, `exp` each entry (scalar in every arm),
/// normalize by the storage-order sum. Empty segments are a no-op.
#[inline]
pub fn row_softmax(lvl: SimdLevel, scores: &mut [f32]) {
    if scores.is_empty() {
        return;
    }
    let m = row_max(lvl, scores);
    let mut denom = 0.0f32;
    for e in scores.iter_mut() {
        *e = (*e - m).exp();
        denom += *e;
    }
    scale_div(lvl, scores, denom);
}

/// Max of a non-empty score slice. Vector arms tree-reduce full 8-lane
/// blocks then fold the remainder — safe under the exact-selection
/// argument in the module docs.
#[inline]
fn row_max(lvl: SimdLevel, s: &[f32]) -> f32 {
    match lvl {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `level()` only reports Avx2 after runtime detection.
        SimdLevel::Avx2 => unsafe { row_max_avx2(s) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: `level()` only reports Neon after runtime detection.
        SimdLevel::Neon => unsafe { row_max_neon(s) },
        _ => s.iter().fold(f32::NEG_INFINITY, |m, &e| if e > m { e } else { m }),
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn row_max_avx2(s: &[f32]) -> f32 {
    use core::arch::x86_64::*;
    let mut m = f32::NEG_INFINITY;
    let mut k = 0usize;
    if s.len() >= 8 {
        let mut acc = _mm256_set1_ps(f32::NEG_INFINITY);
        while k + 8 <= s.len() {
            // max_ps(x, acc) = x > acc ? x : acc — the scalar select.
            acc = _mm256_max_ps(_mm256_loadu_ps(s.as_ptr().add(k)), acc);
            k += 8;
        }
        let mut lanes = [0.0f32; 8];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        for &l in &lanes {
            if l > m {
                m = l;
            }
        }
    }
    for &e in &s[k..] {
        if e > m {
            m = e;
        }
    }
    m
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn row_max_neon(s: &[f32]) -> f32 {
    use core::arch::aarch64::*;
    let mut m = f32::NEG_INFINITY;
    let mut k = 0usize;
    if s.len() >= 4 {
        let mut acc = vdupq_n_f32(f32::NEG_INFINITY);
        while k + 4 <= s.len() {
            let x = vld1q_f32(s.as_ptr().add(k));
            // compare-select (not fmax): exact scalar `>` semantics.
            acc = vbslq_f32(vcgtq_f32(x, acc), x, acc);
            k += 4;
        }
        let mut lanes = [0.0f32; 4];
        vst1q_f32(lanes.as_mut_ptr(), acc);
        for &l in &lanes {
            if l > m {
                m = l;
            }
        }
    }
    for &e in &s[k..] {
        if e > m {
            m = e;
        }
    }
    m
}

/// `s[e] /= denom` for every entry — per-element IEEE divide, exact in
/// every arm.
#[inline]
fn scale_div(lvl: SimdLevel, s: &mut [f32], denom: f32) {
    match lvl {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `level()` only reports Avx2 after runtime detection.
        SimdLevel::Avx2 => unsafe { scale_div_avx2(s, denom) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: `level()` only reports Neon after runtime detection.
        SimdLevel::Neon => unsafe { scale_div_neon(s, denom) },
        _ => {
            for e in s.iter_mut() {
                *e /= denom;
            }
        }
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn scale_div_avx2(s: &mut [f32], denom: f32) {
    use core::arch::x86_64::*;
    let d = _mm256_set1_ps(denom);
    let mut k = 0usize;
    while k + 8 <= s.len() {
        let x = _mm256_loadu_ps(s.as_ptr().add(k));
        _mm256_storeu_ps(s.as_mut_ptr().add(k), _mm256_div_ps(x, d));
        k += 8;
    }
    for e in &mut s[k..] {
        *e /= denom;
    }
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn scale_div_neon(s: &mut [f32], denom: f32) {
    use core::arch::aarch64::*;
    let d = vdupq_n_f32(denom);
    let mut k = 0usize;
    while k + 4 <= s.len() {
        let x = vld1q_f32(s.as_ptr().add(k));
        vst1q_f32(s.as_mut_ptr().add(k), vdivq_f32(x, d));
        k += 4;
    }
    for e in &mut s[k..] {
        *e /= denom;
    }
}

/// GAT attention coefficients over an exact (CSR) operand: per-edge
/// LeakyReLU logits in storage order, then [`row_softmax`] per row.
/// Returns a full `val`-length vector (α for every edge).
pub fn gat_alpha_csr(lvl: SimdLevel, csr: &Csr, s_src: &[f32], s_dst: &[f32]) -> Vec<f32> {
    assert_eq!(s_src.len(), csr.n_rows, "s_src is not [n_rows]");
    assert_eq!(s_dst.len(), csr.n_cols, "s_dst is not [n_cols]");
    let mut alpha = vec![0.0f32; csr.val.len()];
    alpha_csr_rows(lvl, csr, s_src, s_dst, 0..csr.n_rows, &mut alpha);
    alpha
}

/// Row-partitioned [`gat_alpha_csr`] on the global pool — the softmax
/// is row-local, so the result is bitwise equal to serial.
pub fn gat_alpha_csr_par(
    lvl: SimdLevel,
    csr: &Csr,
    s_src: &[f32],
    s_dst: &[f32],
    threads: usize,
) -> Vec<f32> {
    assert_eq!(s_src.len(), csr.n_rows, "s_src is not [n_rows]");
    assert_eq!(s_dst.len(), csr.n_cols, "s_dst is not [n_cols]");
    let n = csr.n_rows;
    let mut alpha = vec![0.0f32; csr.val.len()];
    let parts = threads.max(1).min(n.max(1));
    let chunk = n.div_ceil(parts);
    let mut rest: &mut [f32] = &mut alpha;
    let mut taken = 0usize;
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(parts);
    for part in 0..parts {
        let lo = part * chunk;
        let hi = ((part + 1) * chunk).min(n);
        if lo >= hi {
            break;
        }
        // Edge ranges follow row boundaries, so chunks split cleanly.
        let lo_e = csr.row_ptr[lo] as usize;
        let hi_e = csr.row_ptr[hi] as usize;
        let (alpha_chunk, r) = rest.split_at_mut(hi_e - lo_e);
        rest = r;
        taken = hi_e;
        tasks.push(Box::new(move || {
            alpha_csr_rows(lvl, csr, s_src, s_dst, lo..hi, alpha_chunk)
        }));
    }
    debug_assert_eq!(taken, csr.val.len());
    crate::exec::global_pool().run(tasks);
    alpha
}

/// `alpha_out` covers exactly the edges of `rows` (chunk-local base).
fn alpha_csr_rows(
    lvl: SimdLevel,
    csr: &Csr,
    s_src: &[f32],
    s_dst: &[f32],
    rows: std::ops::Range<usize>,
    alpha_out: &mut [f32],
) {
    let base = csr.row_ptr[rows.start] as usize;
    for i in rows {
        let si = s_src[i];
        let lo = csr.row_ptr[i] as usize - base;
        let hi = csr.row_ptr[i + 1] as usize - base;
        let seg = &mut alpha_out[lo..hi];
        for (a, e) in seg.iter_mut().zip(csr.row_range(i)) {
            *a = leaky_relu(si + s_dst[csr.col_ind[e] as usize]);
        }
        row_softmax(lvl, seg);
    }
}

/// GAT attention coefficients over a sampled (ELL) operand: the softmax
/// runs over each row's surviving slots only (sampled renormalization);
/// padding slots stay `0.0` so [`Ell::validate`]'s contract holds for
/// the substituted plan.
pub fn gat_alpha_ell(lvl: SimdLevel, ell: &Ell, s_src: &[f32], s_dst: &[f32]) -> Vec<f32> {
    assert_eq!(s_src.len(), ell.n_rows, "s_src is not [n_rows]");
    assert_eq!(s_dst.len(), ell.n_cols, "s_dst is not [n_cols]");
    let mut alpha = vec![0.0f32; ell.val.len()];
    alpha_ell_rows(lvl, ell, s_src, s_dst, 0..ell.n_rows, &mut alpha);
    alpha
}

/// Row-partitioned [`gat_alpha_ell`] — bitwise equal to serial.
pub fn gat_alpha_ell_par(
    lvl: SimdLevel,
    ell: &Ell,
    s_src: &[f32],
    s_dst: &[f32],
    threads: usize,
) -> Vec<f32> {
    assert_eq!(s_src.len(), ell.n_rows, "s_src is not [n_rows]");
    assert_eq!(s_dst.len(), ell.n_cols, "s_dst is not [n_cols]");
    let n = ell.n_rows;
    let w = ell.width;
    let mut alpha = vec![0.0f32; ell.val.len()];
    let parts = threads.max(1).min(n.max(1));
    let chunk = n.div_ceil(parts);
    let mut rest: &mut [f32] = &mut alpha;
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(parts);
    for part in 0..parts {
        let lo = part * chunk;
        let hi = ((part + 1) * chunk).min(n);
        if lo >= hi {
            break;
        }
        let (alpha_chunk, r) = rest.split_at_mut((hi - lo) * w);
        rest = r;
        tasks.push(Box::new(move || {
            alpha_ell_rows(lvl, ell, s_src, s_dst, lo..hi, alpha_chunk)
        }));
    }
    crate::exec::global_pool().run(tasks);
    alpha
}

/// `alpha_out` covers exactly the `width`-strided slots of `rows`.
fn alpha_ell_rows(
    lvl: SimdLevel,
    ell: &Ell,
    s_src: &[f32],
    s_dst: &[f32],
    rows: std::ops::Range<usize>,
    alpha_out: &mut [f32],
) {
    let w = ell.width;
    let lo_row = rows.start;
    for i in rows {
        let si = s_src[i];
        let slots = ell.slots[i] as usize;
        let lo = (i - lo_row) * w;
        let seg = &mut alpha_out[lo..lo + slots];
        let cols = &ell.col[i * w..i * w + slots];
        for (a, &c) in seg.iter_mut().zip(cols.iter()) {
            *a = leaky_relu(si + s_dst[c as usize]);
        }
        row_softmax(lvl, seg);
    }
}

/// Segmented elementwise max over an exact operand (GraphSAGE
/// max-pool): `out[i, :] = max_e b[col[e], :]`, `0.0` for edgeless
/// rows. Values are ignored — the pool reads neighbor features only.
/// Lanes are feature columns and the edge walk keeps storage order in
/// every arm, so output is bitwise identical across dispatch levels.
pub fn segmented_max_csr(lvl: SimdLevel, csr: &Csr, b: &[f32], f: usize, out: &mut [f32]) {
    assert_eq!(b.len(), csr.n_cols * f, "B is not [n_cols, f]");
    assert_eq!(out.len(), csr.n_rows * f, "out is not [n_rows, f]");
    for i in 0..csr.n_rows {
        let cols = &csr.col_ind[csr.row_range(i)];
        max_row(lvl, cols, b, f, &mut out[i * f..(i + 1) * f]);
    }
}

/// Row-partitioned [`segmented_max_csr`] — bitwise equal to serial.
pub fn segmented_max_csr_par(
    lvl: SimdLevel,
    csr: &Csr,
    b: &[f32],
    f: usize,
    out: &mut [f32],
    threads: usize,
) {
    assert_eq!(b.len(), csr.n_cols * f, "B is not [n_cols, f]");
    assert_eq!(out.len(), csr.n_rows * f, "out is not [n_rows, f]");
    let n = csr.n_rows;
    let parts = threads.max(1).min(n.max(1));
    let chunk = n.div_ceil(parts);
    let mut rest: &mut [f32] = out;
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(parts);
    for part in 0..parts {
        let lo = part * chunk;
        let hi = ((part + 1) * chunk).min(n);
        if lo >= hi {
            break;
        }
        let (out_chunk, r) = rest.split_at_mut((hi - lo) * f);
        rest = r;
        tasks.push(Box::new(move || {
            for i in lo..hi {
                let cols = &csr.col_ind[csr.row_range(i)];
                max_row(lvl, cols, b, f, &mut out_chunk[(i - lo) * f..(i - lo + 1) * f]);
            }
        }));
    }
    crate::exec::global_pool().run(tasks);
}

/// Segmented elementwise max over a sampled operand: the pool reads the
/// surviving slots only.
pub fn segmented_max_ell(lvl: SimdLevel, ell: &Ell, b: &[f32], f: usize, out: &mut [f32]) {
    assert_eq!(b.len(), ell.n_cols * f, "B is not [n_cols, f]");
    assert_eq!(out.len(), ell.n_rows * f, "out is not [n_rows, f]");
    for i in 0..ell.n_rows {
        let slots = ell.slots[i] as usize;
        let cols = &ell.col[i * ell.width..i * ell.width + slots];
        max_row(lvl, cols, b, f, &mut out[i * f..(i + 1) * f]);
    }
}

/// Row-partitioned [`segmented_max_ell`] — bitwise equal to serial.
pub fn segmented_max_ell_par(
    lvl: SimdLevel,
    ell: &Ell,
    b: &[f32],
    f: usize,
    out: &mut [f32],
    threads: usize,
) {
    assert_eq!(b.len(), ell.n_cols * f, "B is not [n_cols, f]");
    assert_eq!(out.len(), ell.n_rows * f, "out is not [n_rows, f]");
    let n = ell.n_rows;
    let parts = threads.max(1).min(n.max(1));
    let chunk = n.div_ceil(parts);
    let mut rest: &mut [f32] = out;
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(parts);
    for part in 0..parts {
        let lo = part * chunk;
        let hi = ((part + 1) * chunk).min(n);
        if lo >= hi {
            break;
        }
        let (out_chunk, r) = rest.split_at_mut((hi - lo) * f);
        rest = r;
        tasks.push(Box::new(move || {
            for i in lo..hi {
                let slots = ell.slots[i] as usize;
                let cols = &ell.col[i * ell.width..i * ell.width + slots];
                max_row(lvl, cols, b, f, &mut out_chunk[(i - lo) * f..(i - lo + 1) * f]);
            }
        }));
    }
    crate::exec::global_pool().run(tasks);
}

/// One max-pool row: `out = max over cols of b[col, :]`, starting from
/// the first neighbor's features (not `0.0`, so all-negative features
/// pool correctly); edgeless rows emit `0.0`.
#[inline]
fn max_row(lvl: SimdLevel, cols: &[i32], b: &[f32], f: usize, out: &mut [f32]) {
    let Some((&c0, rest)) = cols.split_first() else {
        out.fill(0.0);
        return;
    };
    out.copy_from_slice(&b[c0 as usize * f..c0 as usize * f + f]);
    match lvl {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `level()` only reports Avx2 after runtime detection.
        SimdLevel::Avx2 => unsafe { max_row_avx2(rest, b, f, out) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: `level()` only reports Neon after runtime detection.
        SimdLevel::Neon => unsafe { max_row_neon(rest, b, f, out) },
        _ => {
            for &c in rest {
                let brow = &b[c as usize * f..c as usize * f + f];
                for (o, &x) in out.iter_mut().zip(brow.iter()) {
                    if x > *o {
                        *o = x;
                    }
                }
            }
        }
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn max_row_avx2(cols: &[i32], b: &[f32], f: usize, out: &mut [f32]) {
    use core::arch::x86_64::*;
    let mut k = 0usize;
    while k + 8 <= f {
        let mut acc = _mm256_loadu_ps(out.as_ptr().add(k));
        for &c in cols {
            let x = _mm256_loadu_ps(b.as_ptr().add(c as usize * f + k));
            // max_ps(x, acc) returns acc on ties and NaN inputs — the
            // exact `if x > acc { x } else { acc }` scalar select.
            acc = _mm256_max_ps(x, acc);
        }
        _mm256_storeu_ps(out.as_mut_ptr().add(k), acc);
        k += 8;
    }
    while k < f {
        let mut acc = *out.get_unchecked(k);
        for &c in cols {
            let x = *b.get_unchecked(c as usize * f + k);
            if x > acc {
                acc = x;
            }
        }
        *out.get_unchecked_mut(k) = acc;
        k += 1;
    }
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn max_row_neon(cols: &[i32], b: &[f32], f: usize, out: &mut [f32]) {
    use core::arch::aarch64::*;
    let mut k = 0usize;
    while k + 4 <= f {
        let mut acc = vld1q_f32(out.as_ptr().add(k));
        for &c in cols {
            let x = vld1q_f32(b.as_ptr().add(c as usize * f + k));
            // compare-select (not fmax): exact scalar `>` semantics.
            acc = vbslq_f32(vcgtq_f32(x, acc), x, acc);
        }
        vst1q_f32(out.as_mut_ptr().add(k), acc);
        k += 4;
    }
    while k < f {
        let mut acc = *out.get_unchecked(k);
        for &c in cols {
            let x = *b.get_unchecked(c as usize * f + k);
            if x > acc {
                acc = x;
            }
        }
        *out.get_unchecked_mut(k) = acc;
        k += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;
    use crate::sampling::{sample_ell, Strategy};
    use crate::spmm::simd::level;

    fn toy_csr() -> Csr {
        // 4 rows: [0,1], [2], [], [0,1,2,3]
        Csr {
            n_rows: 4,
            n_cols: 4,
            row_ptr: vec![0, 2, 3, 3, 7],
            col_ind: vec![0, 1, 2, 0, 1, 2, 3],
            val: vec![1.0; 7],
        }
    }

    #[test]
    fn leaky_relu_reference_points() {
        assert_eq!(leaky_relu(2.0), 2.0);
        assert_eq!(leaky_relu(-1.0), -0.2);
        assert_eq!(leaky_relu(0.0), 0.0);
        assert_eq!(leaky_relu(-0.0).to_bits(), 0.0f32.to_bits());
    }

    #[test]
    fn softmax_rows_sum_to_one_and_order_correctly() {
        let mut s = vec![1.0f32, 2.0, 3.0, -1.0];
        row_softmax(level(), &mut s);
        let sum: f32 = s.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6, "sum {sum}");
        assert!(s[2] > s[1] && s[1] > s[0] && s[0] > s[3]);
        // Single-entry segment is exactly 1.
        let mut one = vec![42.0f32];
        row_softmax(level(), &mut one);
        assert_eq!(one, vec![1.0]);
        // Empty segment: no-op.
        row_softmax(level(), &mut []);
    }

    #[test]
    fn softmax_is_shift_invariant_under_max_subtraction() {
        // Huge logits that would overflow a naive exp: the max
        // subtraction keeps every exponent ≤ 0.
        let mut big = vec![500.0f32, 499.0, 120.0];
        row_softmax(level(), &mut big);
        assert!(big.iter().all(|a| a.is_finite()));
        let sum: f32 = big.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6);
        let mut small = vec![1.0f32, 0.0, -379.0];
        row_softmax(level(), &mut small);
        // Shifted inputs produce identical coefficients (e−m equal).
        assert_eq!(big, small);
    }

    #[test]
    fn attention_scores_par_matches_serial_bitwise() {
        let mut rng = Pcg32::new(77);
        let (n, d) = (403, 13);
        let h: Vec<f32> = (0..n * d).map(|_| rng.f32() - 0.5).collect();
        let a: Vec<f32> = (0..d).map(|_| rng.f32() - 0.5).collect();
        let serial = attention_scores(&h, &a, n, d);
        for threads in [1, 3, 8] {
            let par = attention_scores_par(&h, &a, n, d, threads);
            assert_eq!(serial, par, "t{threads}");
        }
    }

    #[test]
    fn alpha_csr_handles_empty_and_single_edge_rows() {
        let g = toy_csr();
        let s_src = vec![0.5f32, -1.0, 2.0, 0.0];
        let s_dst = vec![0.1f32, 0.2, -0.3, 0.4];
        let alpha = gat_alpha_csr(level(), &g, &s_src, &s_dst);
        assert_eq!(alpha.len(), 7);
        // Row 1 has one edge: α must be exactly 1.
        assert_eq!(alpha[2], 1.0);
        // Rows 0 and 3 sum to 1.
        let r0: f32 = alpha[0..2].iter().sum();
        let r3: f32 = alpha[3..7].iter().sum();
        assert!((r0 - 1.0).abs() < 1e-6 && (r3 - 1.0).abs() < 1e-6);
    }

    #[test]
    fn alpha_par_and_ell_match_csr_on_unsampled_width() {
        let mut rng = Pcg32::new(5);
        let g = crate::gen::with_self_loops(&crate::gen::chung_lu(300, 9.0, 1.8, &mut rng));
        let s_src: Vec<f32> = (0..g.n_rows).map(|_| rng.f32() - 0.5).collect();
        let s_dst: Vec<f32> = (0..g.n_cols).map(|_| rng.f32() - 0.5).collect();
        let serial = gat_alpha_csr(level(), &g, &s_src, &s_dst);
        for threads in [1, 3, 8] {
            let par = gat_alpha_csr_par(level(), &g, &s_src, &s_dst, threads);
            assert_eq!(serial, par, "t{threads}");
        }
        // Width ≥ max degree keeps every edge: ELL α equals CSR α
        // edge for edge.
        let w = g.max_degree();
        let ell = sample_ell(&g, w, Strategy::Aes);
        let ea = gat_alpha_ell(level(), &ell, &s_src, &s_dst);
        for i in 0..g.n_rows {
            let s = ell.slots[i] as usize;
            assert_eq!(s, g.row_nnz(i));
            let base = g.row_ptr[i] as usize;
            for k in 0..s {
                assert_eq!(ea[i * w + k].to_bits(), serial[base + k].to_bits(), "row {i} slot {k}");
            }
        }
        let eap = gat_alpha_ell_par(level(), &ell, &s_src, &s_dst, 5);
        assert_eq!(ea, eap);
    }

    #[test]
    fn max_pool_matches_reference_and_handles_empty_rows() {
        let g = toy_csr();
        let f = 3usize;
        let mut rng = Pcg32::new(11);
        let b: Vec<f32> = (0..g.n_cols * f).map(|_| rng.f32() - 0.9).collect();
        let mut got = vec![7.0f32; g.n_rows * f];
        segmented_max_csr(level(), &g, &b, f, &mut got);
        // Empty row → 0.0 (not stale, not -inf).
        assert_eq!(&got[2 * f..3 * f], &[0.0, 0.0, 0.0]);
        // Reference per element.
        for i in 0..g.n_rows {
            for j in 0..f {
                let want = g.row_range(i).fold(None, |m: Option<f32>, e| {
                    let x = b[g.col_ind[e] as usize * f + j];
                    Some(match m {
                        Some(m) if m >= x => m,
                        _ => x,
                    })
                });
                assert_eq!(got[i * f + j], want.unwrap_or(0.0), "({i},{j})");
            }
        }
        // Negative features must pool to a negative max, not 0.0.
        assert!(got[..2 * f].iter().any(|&x| x < 0.0));
    }

    #[test]
    fn max_pool_par_and_ell_variants_are_bitwise() {
        let mut rng = Pcg32::new(23);
        let g = crate::gen::with_self_loops(&crate::gen::chung_lu(250, 7.0, 1.9, &mut rng));
        for f in [1usize, 3, 8, 11] {
            let b: Vec<f32> = (0..g.n_cols * f).map(|_| rng.f32() - 0.5).collect();
            let mut serial = vec![0.0f32; g.n_rows * f];
            segmented_max_csr(level(), &g, &b, f, &mut serial);
            for threads in [1, 4] {
                let mut par = vec![9.0f32; g.n_rows * f];
                segmented_max_csr_par(level(), &g, &b, f, &mut par, threads);
                assert_eq!(serial, par, "f{f} t{threads}");
            }
            let ell = sample_ell(&g, g.max_degree(), Strategy::Aes);
            let mut from_ell = vec![0.0f32; g.n_rows * f];
            segmented_max_ell(level(), &ell, &b, f, &mut from_ell);
            assert_eq!(serial, from_ell, "f{f} ell");
            let mut from_ell_par = vec![0.0f32; g.n_rows * f];
            segmented_max_ell_par(level(), &ell, &b, f, &mut from_ell_par, 4);
            assert_eq!(serial, from_ell_par, "f{f} ell par");
        }
    }
}
