//! Exact CSR SpMM kernels (no sampling, no accuracy loss).

use crate::graph::Csr;

/// Straightforward CSR SpMM — the cuSPARSE-role baseline.
///
/// One pass per row; inner loop over nonzeros, fanning out across the
/// feature dimension. `out` must be `n_rows * f`, zeroed by the callee.
pub fn csr_naive(csr: &Csr, b: &[f32], f: usize, out: &mut [f32]) {
    assert_eq!(b.len(), csr.n_cols * f);
    assert_eq!(out.len(), csr.n_rows * f);
    out.fill(0.0);
    for i in 0..csr.n_rows {
        let row_out = &mut out[i * f..(i + 1) * f];
        for e in csr.row_range(i) {
            let v = csr.val[e];
            let col = csr.col_ind[e] as usize;
            let brow = &b[col * f..col * f + f];
            for (o, &x) in row_out.iter_mut().zip(brow.iter()) {
                *o += v * x;
            }
        }
    }
}

/// Row-cache tile size — the "shared memory" stand-in. 256 entries of
/// (f32, i32) = 2 KiB, comfortably L1-resident. Public because kernel
/// dispatch keys on it: rows within one tile accumulate in plain edge
/// order (bitwise-identical to [`csr_naive`]), rows beyond it introduce
/// per-tile partial sums (different FP order).
pub const TILE: usize = 256;

/// Feature-column block width for warp-merged accumulation (CWM analog).
const FBLOCK: usize = 8;

/// GE-SpMM analog: Coalesced Row Caching + Coarse-grained Warp Merging.
///
/// CRC: the row's (val, col) pairs are staged into a fixed stack tile so
/// the inner feature loop reads them from L1 with unit stride — the CPU
/// equivalent of GE-SpMM caching the row segment in GPU shared memory.
/// CWM: features are processed in blocks of `FBLOCK` accumulated in
/// registers, the analog of one warp covering several columns.
pub fn csr_rowcache(csr: &Csr, b: &[f32], f: usize, out: &mut [f32]) {
    assert_eq!(b.len(), csr.n_cols * f);
    assert_eq!(out.len(), csr.n_rows * f);
    out.fill(0.0);
    let mut tile_val = [0.0f32; TILE];
    let mut tile_col = [0usize; TILE];
    for i in 0..csr.n_rows {
        let range = csr.row_range(i);
        let row_out = &mut out[i * f..(i + 1) * f];
        let mut lo = range.start;
        while lo < range.end {
            let len = (range.end - lo).min(TILE);
            // CRC: stage the segment.
            for t in 0..len {
                tile_val[t] = csr.val[lo + t];
                tile_col[t] = csr.col_ind[lo + t] as usize;
            }
            // CWM: feature blocks in registers.
            let mut k = 0;
            while k + FBLOCK <= f {
                let mut acc = [0.0f32; FBLOCK];
                for t in 0..len {
                    let brow = &b[tile_col[t] * f + k..tile_col[t] * f + k + FBLOCK];
                    let v = tile_val[t];
                    for (a, &x) in acc.iter_mut().zip(brow.iter()) {
                        *a += v * x;
                    }
                }
                for (o, a) in row_out[k..k + FBLOCK].iter_mut().zip(acc.iter()) {
                    *o += a;
                }
                k += FBLOCK;
            }
            // Remainder columns.
            while k < f {
                let mut acc = 0.0f32;
                for t in 0..len {
                    acc += tile_val[t] * b[tile_col[t] * f + k];
                }
                row_out[k] += acc;
                k += 1;
            }
            lo += len;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spmm::testutil::{assert_close, dense_ref, random_graph_and_features};

    #[test]
    fn naive_matches_dense_reference() {
        let (g, b) = random_graph_and_features(300, 12.0, 17, 1);
        let mut out = vec![0.0; g.n_rows * 17];
        csr_naive(&g, &b, 17, &mut out);
        assert_close(&out, &dense_ref(&g, &b, 17), 1e-5);
    }

    #[test]
    fn rowcache_matches_naive() {
        for (n, deg, f) in [(200, 8.0, 16), (100, 50.0, 33), (64, 300.0, 8)] {
            let (g, b) = random_graph_and_features(n, deg, f, 2);
            let mut a = vec![0.0; g.n_rows * f];
            let mut c = vec![0.0; g.n_rows * f];
            csr_naive(&g, &b, f, &mut a);
            csr_rowcache(&g, &b, f, &mut c);
            assert_close(&a, &c, 1e-5);
        }
    }

    #[test]
    fn empty_rows_produce_zeros() {
        let g = Csr::new(3, 3, vec![0, 0, 1, 1], vec![2], vec![5.0]).unwrap();
        let b = vec![1.0; 9];
        let mut out = vec![7.0; 9]; // dirty buffer — kernel must clear it
        csr_rowcache(&g, &b, 3, &mut out);
        assert_eq!(&out[0..3], &[0.0, 0.0, 0.0]);
        assert_eq!(&out[3..6], &[5.0, 5.0, 5.0]);
        assert_eq!(&out[6..9], &[0.0, 0.0, 0.0]);
    }

    #[test]
    fn feature_dim_one() {
        let (g, b) = random_graph_and_features(100, 10.0, 1, 3);
        let mut a = vec![0.0; 100];
        let mut c = vec![0.0; 100];
        csr_naive(&g, &b, 1, &mut a);
        csr_rowcache(&g, &b, 1, &mut c);
        assert_close(&a, &c, 1e-5);
    }
}
