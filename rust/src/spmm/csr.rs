//! Exact CSR SpMM kernels (no sampling, no accuracy loss).

use crate::graph::Csr;
use crate::spmm::simd::{self, SimdLevel};

/// Straightforward CSR SpMM — the cuSPARSE-role baseline.
///
/// One pass per row; inner loop over nonzeros, fanning out across the
/// feature dimension. `out` must be `n_rows * f`, zeroed by the callee.
/// Deliberately scalar on every machine: this is the canonical FP
/// reduction order the eval oracle and the SIMD arms are measured
/// against.
pub fn csr_naive(csr: &Csr, b: &[f32], f: usize, out: &mut [f32]) {
    assert_eq!(b.len(), csr.n_cols * f);
    assert_eq!(out.len(), csr.n_rows * f);
    out.fill(0.0);
    csr_naive_rows(csr, b, f, 0..csr.n_rows, out);
}

/// Row-range worker behind [`csr_naive`] and the threaded wrapper:
/// computes rows `rows` into the chunk-local `out` (`rows.len() * f`,
/// pre-zeroed by the caller).
pub(crate) fn csr_naive_rows(
    csr: &Csr,
    b: &[f32],
    f: usize,
    rows: std::ops::Range<usize>,
    out: &mut [f32],
) {
    for (oi, i) in rows.enumerate() {
        let row_out = &mut out[oi * f..(oi + 1) * f];
        for e in csr.row_range(i) {
            let v = csr.val[e];
            let col = csr.col_ind[e] as usize;
            let brow = &b[col * f..col * f + f];
            for (o, &x) in row_out.iter_mut().zip(brow.iter()) {
                *o += v * x;
            }
        }
    }
}

/// Floor of the row-cache staging tile — the "shared memory" stand-in.
/// Public because kernel dispatch keys on it: the tuned runtime tile
/// ([`crate::spmm::simd::edge_tile`]) is always ≥ this, so any row of
/// at most `TILE` nonzeros fits a single tile on every machine and
/// accumulates in plain edge order (bitwise-identical to
/// [`csr_naive`]); longer rows introduce per-tile partial sums whose
/// boundaries depend on the detected L1d, which is why the dispatch
/// gate keeps them on the naive kernel.
pub const TILE: usize = simd::EDGE_TILE_MIN;

/// GE-SpMM analog: Coalesced Row Caching + Coarse-grained Warp Merging,
/// dispatched at the detected SIMD level.
///
/// CRC: the row's (val, col) pairs are staged into an L1-sized tile
/// (tuned from the detected cache profile) so the inner feature loop
/// reads them with unit stride — the CPU equivalent of GE-SpMM caching
/// the row segment in GPU shared memory. CWM: features are processed in
/// 8-column register blocks, the analog of one warp covering several
/// columns; on AVX2/NEON the block is a vector register.
pub fn csr_rowcache(csr: &Csr, b: &[f32], f: usize, out: &mut [f32]) {
    csr_rowcache_at(simd::level(), csr, b, f, out)
}

/// [`csr_rowcache`] pinned to an explicit SIMD level — the bitwise
/// cross-checks in tests and the scalar-vs-SIMD bench cases use this;
/// serving code should call [`csr_rowcache`].
pub fn csr_rowcache_at(lvl: SimdLevel, csr: &Csr, b: &[f32], f: usize, out: &mut [f32]) {
    assert_eq!(b.len(), csr.n_cols * f);
    assert_eq!(out.len(), csr.n_rows * f);
    out.fill(0.0);
    let tile = simd::edge_tile();
    let mut tile_val = vec![0.0f32; tile];
    let mut tile_col = vec![0usize; tile];
    for i in 0..csr.n_rows {
        let range = csr.row_range(i);
        let row_out = &mut out[i * f..(i + 1) * f];
        let mut lo = range.start;
        while lo < range.end {
            let len = (range.end - lo).min(tile);
            // CRC: stage the segment.
            for t in 0..len {
                tile_val[t] = csr.val[lo + t];
                tile_col[t] = csr.col_ind[lo + t] as usize;
            }
            // CWM: register-blocked feature accumulation.
            simd::tile_axpy(lvl, &tile_val[..len], &tile_col[..len], b, f, row_out);
            lo += len;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spmm::testutil::{assert_close, dense_ref, random_graph_and_features};

    #[test]
    fn naive_matches_dense_reference() {
        let (g, b) = random_graph_and_features(300, 12.0, 17, 1);
        let mut out = vec![0.0; g.n_rows * 17];
        csr_naive(&g, &b, 17, &mut out);
        assert_close(&out, &dense_ref(&g, &b, 17), 1e-5);
    }

    #[test]
    fn rowcache_matches_naive() {
        for (n, deg, f) in [(200, 8.0, 16), (100, 50.0, 33), (64, 300.0, 8)] {
            let (g, b) = random_graph_and_features(n, deg, f, 2);
            let mut a = vec![0.0; g.n_rows * f];
            let mut c = vec![0.0; g.n_rows * f];
            csr_naive(&g, &b, f, &mut a);
            csr_rowcache(&g, &b, f, &mut c);
            assert_close(&a, &c, 1e-5);
        }
    }

    #[test]
    fn empty_rows_produce_zeros() {
        let g = Csr::new(3, 3, vec![0, 0, 1, 1], vec![2], vec![5.0]).unwrap();
        let b = vec![1.0; 9];
        let mut out = vec![7.0; 9]; // dirty buffer — kernel must clear it
        csr_rowcache(&g, &b, 3, &mut out);
        assert_eq!(&out[0..3], &[0.0, 0.0, 0.0]);
        assert_eq!(&out[3..6], &[5.0, 5.0, 5.0]);
        assert_eq!(&out[6..9], &[0.0, 0.0, 0.0]);
    }

    #[test]
    fn feature_dim_one() {
        let (g, b) = random_graph_and_features(100, 10.0, 1, 3);
        let mut a = vec![0.0; 100];
        let mut c = vec![0.0; 100];
        csr_naive(&g, &b, 1, &mut a);
        csr_rowcache(&g, &b, 1, &mut c);
        assert_close(&a, &c, 1e-5);
    }

    #[test]
    fn rowcache_simd_matches_scalar_bitwise() {
        // Remainder lanes (f off the 8-lane width), empty rows (sparse
        // graph), and single-tile rows.
        for f in [1usize, 7, 8, 9, 33] {
            let (g, b) = random_graph_and_features(150, 6.0, f, 11 + f as u64);
            let mut scalar = vec![0.0; g.n_rows * f];
            let mut vector = vec![0.0; g.n_rows * f];
            csr_rowcache_at(SimdLevel::Scalar, &g, &b, f, &mut scalar);
            csr_rowcache_at(simd::level(), &g, &b, f, &mut vector);
            assert_eq!(scalar, vector, "f={f}");
        }
    }

    #[test]
    fn rowcache_mega_row_simd_matches_scalar_bitwise() {
        // One row denser than the staging tile: partial-sum boundaries
        // come from the tuned tile, which is level-independent, so the
        // arms must still agree bitwise (and stay close to naive).
        let n = simd::edge_tile() + 500;
        let col_ind: Vec<i32> = (0..n as i32).collect();
        let val: Vec<f32> = (0..n).map(|i| (i as f32 * 0.37).sin()).collect();
        let mut row_ptr = vec![0i32; 2];
        row_ptr[1] = n as i32;
        row_ptr.extend(std::iter::repeat(n as i32).take(n - 1));
        let g = Csr::new(n, n, row_ptr, col_ind, val).unwrap();
        let f = 9usize;
        let b: Vec<f32> = (0..n * f).map(|i| (i as f32 * 0.11).cos()).collect();
        let mut scalar = vec![0.0; n * f];
        let mut vector = vec![0.0; n * f];
        csr_rowcache_at(SimdLevel::Scalar, &g, &b, f, &mut scalar);
        csr_rowcache_at(simd::level(), &g, &b, f, &mut vector);
        assert_eq!(scalar, vector);
        let mut naive = vec![0.0; n * f];
        csr_naive(&g, &b, f, &mut naive);
        assert_close(&scalar, &naive, 1e-4);
    }
}
