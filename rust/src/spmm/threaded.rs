//! Row-partitioned multi-threaded SpMM wrappers (std::thread::scope; the
//! offline registry has no rayon). Rows are split into contiguous chunks
//! balanced by nnz, mirroring how the GPU kernels assign row segments to
//! thread blocks.

use crate::graph::{Csr, Ell};

/// Split `n_rows` into `parts` contiguous chunks with roughly equal nnz.
fn balance_rows(row_nnz: impl Fn(usize) -> usize, n_rows: usize, parts: usize) -> Vec<std::ops::Range<usize>> {
    let total: usize = (0..n_rows).map(&row_nnz).sum();
    let per = (total / parts.max(1)).max(1);
    let mut out = Vec::with_capacity(parts);
    let mut start = 0usize;
    let mut acc = 0usize;
    for i in 0..n_rows {
        acc += row_nnz(i);
        if acc >= per && out.len() + 1 < parts {
            out.push(start..i + 1);
            start = i + 1;
            acc = 0;
        }
    }
    out.push(start..n_rows);
    out
}

/// Parallel exact CSR SpMM (cuSPARSE-role baseline, multi-core).
pub fn csr_naive_par(csr: &Csr, b: &[f32], f: usize, out: &mut [f32], threads: usize) {
    assert_eq!(out.len(), csr.n_rows * f);
    let chunks = balance_rows(|i| csr.row_nnz(i), csr.n_rows, threads.max(1));
    // Split the output buffer along the same row boundaries.
    let mut slices: Vec<&mut [f32]> = Vec::with_capacity(chunks.len());
    let mut rest = out;
    let mut prev_end = 0usize;
    for r in &chunks {
        let (head, tail) = rest.split_at_mut((r.end - prev_end) * f);
        slices.push(head);
        rest = tail;
        prev_end = r.end;
    }
    std::thread::scope(|s| {
        for (range, slice) in chunks.into_iter().zip(slices.into_iter()) {
            s.spawn(move || {
                slice.fill(0.0);
                for i in range.clone() {
                    let local = &mut slice[(i - range.start) * f..(i - range.start + 1) * f];
                    for e in csr.row_range(i) {
                        let v = csr.val[e];
                        let col = csr.col_ind[e] as usize;
                        let brow = &b[col * f..col * f + f];
                        for (o, &x) in local.iter_mut().zip(brow.iter()) {
                            *o += v * x;
                        }
                    }
                }
            });
        }
    });
}

/// Parallel sampled (ELL) SpMM.
pub fn ell_spmm_par(ell: &Ell, b: &[f32], f: usize, out: &mut [f32], threads: usize) {
    assert_eq!(out.len(), ell.n_rows * f);
    let w = ell.width;
    let chunks = balance_rows(|i| ell.slots[i] as usize, ell.n_rows, threads.max(1));
    let mut slices: Vec<&mut [f32]> = Vec::with_capacity(chunks.len());
    let mut rest = out;
    let mut prev_end = 0usize;
    for r in &chunks {
        let (head, tail) = rest.split_at_mut((r.end - prev_end) * f);
        slices.push(head);
        rest = tail;
        prev_end = r.end;
    }
    std::thread::scope(|s| {
        for (range, slice) in chunks.into_iter().zip(slices.into_iter()) {
            s.spawn(move || {
                slice.fill(0.0);
                for i in range.clone() {
                    let local = &mut slice[(i - range.start) * f..(i - range.start + 1) * f];
                    let vals = &ell.val[i * w..i * w + ell.slots[i] as usize];
                    let cols = &ell.col[i * w..i * w + ell.slots[i] as usize];
                    for (v, &c) in vals.iter().zip(cols.iter()) {
                        let brow = &b[c as usize * f..c as usize * f + f];
                        for (o, &x) in local.iter_mut().zip(brow.iter()) {
                            *o += v * x;
                        }
                    }
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampling::{sample_ell, Strategy};
    use crate::spmm::testutil::{assert_close, random_graph_and_features};
    use crate::spmm::{csr_naive, ell_spmm};

    #[test]
    fn balance_covers_all_rows_disjointly() {
        let nnz = [5usize, 0, 100, 3, 3, 3, 50, 1];
        for parts in 1..=6 {
            let chunks = balance_rows(|i| nnz[i], nnz.len(), parts);
            assert!(chunks.len() <= parts);
            let mut next = 0;
            for c in &chunks {
                assert_eq!(c.start, next);
                next = c.end;
            }
            assert_eq!(next, nnz.len());
        }
    }

    #[test]
    fn par_csr_matches_serial() {
        let (g, b) = random_graph_and_features(500, 25.0, 13, 7);
        let mut serial = vec![0.0; g.n_rows * 13];
        csr_naive(&g, &b, 13, &mut serial);
        for threads in [1, 2, 4, 7] {
            let mut par = vec![0.0; g.n_rows * 13];
            csr_naive_par(&g, &b, 13, &mut par, threads);
            assert_close(&serial, &par, 1e-6);
        }
    }

    #[test]
    fn par_ell_matches_serial() {
        let (g, b) = random_graph_and_features(400, 60.0, 8, 8);
        let ell = sample_ell(&g, 32, Strategy::Aes);
        let mut serial = vec![0.0; g.n_rows * 8];
        ell_spmm(&ell, &b, 8, &mut serial);
        for threads in [2, 3, 8] {
            let mut par = vec![0.0; g.n_rows * 8];
            ell_spmm_par(&ell, &b, 8, &mut par, threads);
            assert_close(&serial, &par, 1e-6);
        }
    }
}
