//! Row-partitioned multi-threaded SpMM wrappers. Rows are split into
//! contiguous chunks balanced by nnz (mirroring how the GPU kernels
//! assign row segments to thread blocks) and executed on the persistent
//! [`crate::exec`] worker pool — no OS threads are spawned per call.

use crate::exec;
use crate::graph::{Csr, Ell};

/// Split `n_rows` into at most `parts` contiguous, **non-empty** chunks
/// with roughly equal nnz — a thin wrapper over the shared
/// [`crate::graph::balanced_cuts`] quantile cutter (the same substrate
/// the shard partitioner uses), fed by an inline nnz prefix sum.
///
/// Degenerate inputs are clamped rather than mis-split: `parts` is capped
/// at `n_rows` (never more chunks than rows), zero/tiny total nnz falls
/// back to even row counts, and `n_rows == 0` yields one empty chunk.
pub(crate) fn balance_rows(
    row_nnz: impl Fn(usize) -> usize,
    n_rows: usize,
    parts: usize,
) -> Vec<std::ops::Range<usize>> {
    let mut prefix = Vec::with_capacity(n_rows + 1);
    prefix.push(0usize);
    for i in 0..n_rows {
        let p = prefix[i] + row_nnz(i);
        prefix.push(p);
    }
    let out = crate::graph::balanced_cuts(&prefix, parts);

    debug_assert_eq!(out.first().map(|r| r.start), Some(0));
    debug_assert_eq!(out.last().map(|r| r.end), Some(n_rows));
    debug_assert!(out.windows(2).all(|w| w[0].end == w[1].start), "chunks must be contiguous");
    debug_assert!(
        n_rows == 0 || out.iter().all(|r| !r.is_empty()),
        "chunks must be non-empty"
    );
    out
}

/// Split `out` into row-aligned mutable slices matching `chunks`.
pub(crate) fn split_output<'a>(
    out: &'a mut [f32],
    chunks: &[std::ops::Range<usize>],
    f: usize,
) -> Vec<&'a mut [f32]> {
    let mut slices = Vec::with_capacity(chunks.len());
    let mut rest = out;
    let mut prev_end = 0usize;
    for r in chunks {
        let (head, tail) = rest.split_at_mut((r.end - prev_end) * f);
        slices.push(head);
        rest = tail;
        prev_end = r.end;
    }
    slices
}

/// Parallel exact CSR SpMM (cuSPARSE-role baseline, multi-core).
///
/// `threads` is the chunking factor; execution happens on the shared
/// persistent pool, so asking for more chunks than pool workers simply
/// queues them.
pub fn csr_naive_par(csr: &Csr, b: &[f32], f: usize, out: &mut [f32], threads: usize) {
    assert_eq!(out.len(), csr.n_rows * f);
    let chunks = balance_rows(|i| csr.row_nnz(i), csr.n_rows, threads.max(1));
    let slices = split_output(out, &chunks, f);
    let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = chunks
        .into_iter()
        .zip(slices)
        .map(|(range, slice)| {
            Box::new(move || {
                slice.fill(0.0);
                // Same per-row worker as the serial kernel — chunk cuts
                // land on row boundaries, so rows reduce identically.
                super::csr::csr_naive_rows(csr, b, f, range, slice);
            }) as Box<dyn FnOnce() + Send + '_>
        })
        .collect();
    exec::global_pool().run(tasks);
}

/// Parallel sampled (ELL) SpMM, dispatched at the detected SIMD level
/// (each chunk runs the same [`super::ell`] row worker as the serial
/// kernel, so threading and SIMD compose without changing a bit).
pub fn ell_spmm_par(ell: &Ell, b: &[f32], f: usize, out: &mut [f32], threads: usize) {
    assert_eq!(out.len(), ell.n_rows * f);
    let lvl = crate::spmm::simd::level();
    let chunks = balance_rows(|i| ell.slots[i] as usize, ell.n_rows, threads.max(1));
    let slices = split_output(out, &chunks, f);
    let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = chunks
        .into_iter()
        .zip(slices)
        .map(|(range, slice)| {
            Box::new(move || {
                slice.fill(0.0);
                super::ell::ell_spmm_rows(lvl, ell, b, f, range, slice);
            }) as Box<dyn FnOnce() + Send + '_>
        })
        .collect();
    exec::global_pool().run(tasks);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampling::{sample_ell, Strategy};
    use crate::spmm::testutil::{assert_close, random_graph_and_features};
    use crate::spmm::{csr_naive, ell_spmm};

    fn assert_chunk_invariants(chunks: &[std::ops::Range<usize>], n_rows: usize, parts: usize) {
        assert!(!chunks.is_empty());
        assert!(chunks.len() <= parts);
        let mut next = 0;
        for c in chunks {
            assert_eq!(c.start, next);
            if n_rows > 0 {
                assert!(!c.is_empty(), "empty chunk {c:?} for n_rows={n_rows} parts={parts}");
            }
            next = c.end;
        }
        assert_eq!(next, n_rows);
    }

    #[test]
    fn balance_covers_all_rows_disjointly() {
        let nnz = [5usize, 0, 100, 3, 3, 3, 50, 1];
        for parts in 1..=6 {
            let chunks = balance_rows(|i| nnz[i], nnz.len(), parts);
            assert!(chunks.len() <= parts);
            assert_chunk_invariants(&chunks, nnz.len(), parts);
        }
    }

    #[test]
    fn balance_clamps_more_parts_than_rows() {
        // The seed emitted empty trailing chunks here; now parts is capped
        // at n_rows and every chunk holds at least one row.
        for (n_rows, parts) in [(3usize, 10usize), (1, 8), (5, 5), (7, 100)] {
            let chunks = balance_rows(|i| i + 1, n_rows, parts);
            assert_eq!(chunks.len(), n_rows.min(parts));
            assert_chunk_invariants(&chunks, n_rows, parts);
        }
    }

    #[test]
    fn balance_handles_tiny_or_zero_nnz() {
        // All-zero nnz: fall back to even row cuts, still non-empty.
        let chunks = balance_rows(|_| 0, 9, 4);
        assert_eq!(chunks.len(), 4);
        assert_chunk_invariants(&chunks, 9, 4);

        // One heavy row up front must not starve the trailing chunks.
        let nnz = [1000usize, 0, 0, 0, 0, 0];
        let chunks = balance_rows(|i| nnz[i], nnz.len(), 3);
        assert_chunk_invariants(&chunks, nnz.len(), 3);

        // Empty matrix: a single empty chunk, no panic.
        let chunks = balance_rows(|_| 1, 0, 4);
        assert_eq!(chunks, vec![0..0]);
    }

    #[test]
    fn balance_is_roughly_even_on_uniform_rows() {
        let chunks = balance_rows(|_| 10, 100, 4);
        assert_eq!(chunks.len(), 4);
        for c in &chunks {
            assert_eq!(c.end - c.start, 25);
        }
    }

    #[test]
    fn par_csr_matches_serial() {
        let (g, b) = random_graph_and_features(500, 25.0, 13, 7);
        let mut serial = vec![0.0; g.n_rows * 13];
        csr_naive(&g, &b, 13, &mut serial);
        for threads in [1, 2, 4, 7] {
            let mut par = vec![0.0; g.n_rows * 13];
            csr_naive_par(&g, &b, 13, &mut par, threads);
            assert_close(&serial, &par, 1e-6);
        }
    }

    #[test]
    fn par_csr_with_threads_exceeding_rows() {
        let (g, b) = random_graph_and_features(12, 4.0, 5, 9);
        let mut serial = vec![0.0; g.n_rows * 5];
        csr_naive(&g, &b, 5, &mut serial);
        let mut par = vec![0.0; g.n_rows * 5];
        csr_naive_par(&g, &b, 5, &mut par, 64);
        assert_close(&serial, &par, 1e-6);
    }

    #[test]
    fn par_ell_matches_serial() {
        let (g, b) = random_graph_and_features(400, 60.0, 8, 8);
        let ell = sample_ell(&g, 32, Strategy::Aes);
        let mut serial = vec![0.0; g.n_rows * 8];
        ell_spmm(&ell, &b, 8, &mut serial);
        for threads in [2, 3, 8, 1000] {
            let mut par = vec![0.0; g.n_rows * 8];
            ell_spmm_par(&ell, &b, 8, &mut par, threads);
            assert_close(&serial, &par, 1e-6);
        }
    }
}
