//! Alternative exact sparse formats — the per-shard format zoo behind
//! the tuned dispatcher (`docs/dispatch.md`).
//!
//! Two layouts join CSR and ELL:
//!
//! * [`BlockedCsr`] — CSR with **fixed-height row blocks**: the edge
//!   arrays are the CSR arrays verbatim (so conversion is exact and the
//!   round trip is the identity), plus a per-block edge index. The
//!   kernel walks one block of rows at a time and column-blocks the
//!   feature dim inside the block ([`crate::spmm::simd::feat_block`]),
//!   so the B rows a block touches stay LLC-resident across its rows —
//!   the locality CSR-naive leaves on the table.
//! * [`DenseTile`] — a fixed-pitch row slab for **near-dense** shards:
//!   every row owns `pitch` (val, col) slots (pitch = the longest row,
//!   rounded up to the 8-lane SIMD width), padding zeroed. No `row_ptr`
//!   indirection in the hot loop, unit-stride prefetchable rows, and —
//!   unlike the row-cache kernel — no row-length cap: the whole row
//!   accumulates in one pass, so even mega-rows keep the canonical FP
//!   order. Use [`dense_tile_viable`] to bound the padding blow-up
//!   before building one.
//!
//! # Bitwise contract
//!
//! Both formats keep every edge in **canonical CSR order** and both
//! kernels accumulate each output row per-element in that order via
//! [`crate::spmm::simd::ell_row`] (multiply and add separate, lanes =
//! independent feature columns). Per output element the operation
//! sequence is exactly [`crate::spmm::csr_naive`]'s, so every
//! (format × SIMD arm × thread count) cell is bitwise-identical to the
//! canonical scalar CSR path — `tests/format_equiv.rs` asserts the full
//! grid. The i8 entry points reuse the per-row requantized kernel
//! ([`crate::spmm::AdjQuant`], row-local [`crate::spmm::I8_FLUSH_EDGES`]
//! flush boundaries), which is exact in integer arithmetic, so the same
//! grid holds there by construction.

use crate::graph::Csr;

use super::int8::{i8_row_rescale, AdjQuant};
use super::simd::{self, SimdLevel};
use super::threaded::{balance_rows, split_output};

/// Default fixed block height for [`BlockedCsr`]: enough rows that the
/// per-block feature pass amortizes, small enough that a block's B-row
/// working set stays cache-sized on typical shard profiles.
pub const BCSR_BLOCK_ROWS: usize = 64;

/// CSR with fixed-height row blocks. The edge arrays are the source
/// CSR's arrays verbatim — `block_ptr` only adds a per-block edge
/// index — so [`BlockedCsr::to_csr`] is an exact inverse of
/// [`BlockedCsr::from_csr`] (nnz, values, and canonical edge order all
/// preserved, by construction).
#[derive(Clone, Debug, PartialEq)]
pub struct BlockedCsr {
    /// Rows of the matrix.
    pub n_rows: usize,
    /// Columns of the matrix.
    pub n_cols: usize,
    /// Fixed block height (≥ 1); the last block may be shorter.
    pub block_rows: usize,
    /// Edge offset of each block start: `block_ptr[k]` is the first
    /// edge of block `k`, `block_ptr[n_blocks]` is nnz.
    pub block_ptr: Vec<usize>,
    /// CSR row pointer (verbatim from the source).
    pub row_ptr: Vec<i32>,
    /// CSR column indices (verbatim from the source).
    pub col_ind: Vec<i32>,
    /// CSR values (verbatim from the source).
    pub val: Vec<f32>,
}

impl BlockedCsr {
    /// Build from a CSR with the given block height (clamped to ≥ 1).
    pub fn from_csr(csr: &Csr, block_rows: usize) -> BlockedCsr {
        let h = block_rows.max(1);
        let n_blocks = csr.n_rows.div_ceil(h);
        let block_ptr = (0..=n_blocks)
            .map(|k| csr.row_ptr[(k * h).min(csr.n_rows)] as usize)
            .collect();
        BlockedCsr {
            n_rows: csr.n_rows,
            n_cols: csr.n_cols,
            block_rows: h,
            block_ptr,
            row_ptr: csr.row_ptr.clone(),
            col_ind: csr.col_ind.clone(),
            val: csr.val.clone(),
        }
    }

    /// Exact inverse of [`BlockedCsr::from_csr`].
    pub fn to_csr(&self) -> Csr {
        Csr::new(
            self.n_rows,
            self.n_cols,
            self.row_ptr.clone(),
            self.col_ind.clone(),
            self.val.clone(),
        )
        .expect("a BlockedCsr built from a valid CSR round-trips")
    }

    /// Blocks in the layout.
    pub fn n_blocks(&self) -> usize {
        self.block_ptr.len() - 1
    }

    /// Stored entries.
    pub fn nnz(&self) -> usize {
        self.val.len()
    }

    /// Edge range of row `i`.
    pub fn row_range(&self, i: usize) -> std::ops::Range<usize> {
        self.row_ptr[i] as usize..self.row_ptr[i + 1] as usize
    }
}

/// Fixed-pitch row slab for near-dense shards: every row owns `pitch`
/// (val, col) slots in canonical CSR edge order, padding zeroed.
/// `edge_off` is the source CSR's row pointer verbatim, so the round
/// trip back to CSR is exact and per-edge side data in nnz order (an
/// [`AdjQuant`] built from the CSR) addresses rows directly.
#[derive(Clone, Debug, PartialEq)]
pub struct DenseTile {
    /// Rows of the matrix.
    pub n_rows: usize,
    /// Columns of the matrix.
    pub n_cols: usize,
    /// Slots per row: the longest row rounded up to the 8-lane SIMD
    /// width (≥ 8).
    pub pitch: usize,
    /// Row-major `[n_rows * pitch]` values; padding = 0.0.
    pub val: Vec<f32>,
    /// Row-major `[n_rows * pitch]` column indices; padding = 0.
    pub col: Vec<i32>,
    /// CSR row pointer (verbatim from the source), so
    /// `edge_off[i+1] - edge_off[i]` is row `i`'s valid slot count.
    pub edge_off: Vec<i32>,
}

/// Pitch a dense tile would use for a matrix whose longest row holds
/// `max_deg` entries.
fn dense_pitch(max_deg: usize) -> usize {
    max_deg.max(1).next_multiple_of(8)
}

/// Whether a dense tile of `csr` keeps its padding blow-up within
/// `slack`× the stored entries (per-row floors included) — the guard
/// dispatch uses before materializing one for a shard.
pub fn dense_tile_viable(csr: &Csr, slack: usize) -> bool {
    let padded = dense_pitch(csr.max_degree()).saturating_mul(csr.n_rows);
    padded <= slack.saturating_mul(csr.nnz().max(csr.n_rows))
}

impl DenseTile {
    /// Build from a CSR, keeping every edge in canonical order.
    pub fn from_csr(csr: &Csr) -> DenseTile {
        let pitch = dense_pitch(csr.max_degree());
        let mut t = DenseTile {
            n_rows: csr.n_rows,
            n_cols: csr.n_cols,
            pitch,
            val: vec![0.0; csr.n_rows * pitch],
            col: vec![0; csr.n_rows * pitch],
            edge_off: csr.row_ptr.clone(),
        };
        for i in 0..csr.n_rows {
            let r = csr.row_range(i);
            let n = r.len();
            t.val[i * pitch..i * pitch + n].copy_from_slice(&csr.val[r.clone()]);
            t.col[i * pitch..i * pitch + n].copy_from_slice(&csr.col_ind[r]);
        }
        t
    }

    /// Exact inverse of [`DenseTile::from_csr`].
    pub fn to_csr(&self) -> Csr {
        let nnz = self.nnz();
        let mut col_ind = Vec::with_capacity(nnz);
        let mut val = Vec::with_capacity(nnz);
        for i in 0..self.n_rows {
            let n = self.row_nnz(i);
            val.extend_from_slice(&self.val[i * self.pitch..i * self.pitch + n]);
            col_ind.extend_from_slice(&self.col[i * self.pitch..i * self.pitch + n]);
        }
        Csr::new(self.n_rows, self.n_cols, self.edge_off.clone(), col_ind, val)
            .expect("a DenseTile built from a valid CSR round-trips")
    }

    /// Valid slots in row `i`.
    pub fn row_nnz(&self, i: usize) -> usize {
        (self.edge_off[i + 1] - self.edge_off[i]) as usize
    }

    /// Stored entries (excluding padding).
    pub fn nnz(&self) -> usize {
        self.edge_off.last().map(|&e| e as usize).unwrap_or(0)
    }
}

// ---------------------------------------------------------------------------
// fp32 kernels
// ---------------------------------------------------------------------------

/// Blocked-CSR SpMM at the detected SIMD level.
pub fn bcsr_spmm(m: &BlockedCsr, b: &[f32], f: usize, out: &mut [f32]) {
    bcsr_spmm_at(simd::level(), m, b, f, out)
}

/// [`bcsr_spmm`] pinned to an explicit SIMD level (tests/benches).
pub fn bcsr_spmm_at(lvl: SimdLevel, m: &BlockedCsr, b: &[f32], f: usize, out: &mut [f32]) {
    assert_eq!(b.len(), m.n_cols * f);
    assert_eq!(out.len(), m.n_rows * f);
    out.fill(0.0);
    bcsr_rows(lvl, m, b, f, 0..m.n_rows, out);
}

/// Row-range worker shared by the serial entry and the threaded
/// wrapper: per block, per feature block, per row — each row's edges in
/// canonical order via [`simd::ell_row`], so the per-element FP
/// sequence is exactly the naive kernel's.
fn bcsr_rows(
    lvl: SimdLevel,
    m: &BlockedCsr,
    b: &[f32],
    f: usize,
    rows: std::ops::Range<usize>,
    out: &mut [f32],
) {
    if rows.is_empty() {
        return;
    }
    let kb = simd::feat_block(m.n_cols, f).max(1);
    let h = m.block_rows;
    let first = rows.start / h;
    let last = (rows.end - 1) / h;
    for blk in first..=last {
        if m.block_ptr[blk] == m.block_ptr[blk + 1] {
            continue; // whole block empty; out is pre-zeroed
        }
        let blo = (blk * h).max(rows.start);
        let bhi = ((blk + 1) * h).min(rows.end);
        let mut k0 = 0usize;
        while k0 < f {
            let kw = kb.min(f - k0);
            for i in blo..bhi {
                let r = m.row_range(i);
                if r.is_empty() {
                    continue;
                }
                simd::prefetch_read(&m.col_ind, r.end);
                let oi = i - rows.start;
                simd::ell_row(
                    lvl,
                    &m.val[r.clone()],
                    &m.col_ind[r],
                    b,
                    f,
                    k0,
                    &mut out[oi * f + k0..oi * f + k0 + kw],
                );
            }
            k0 += kw;
        }
    }
}

/// Parallel [`bcsr_spmm`] — row chunks on the shared exec pool, same
/// per-row worker as the serial kernel (bitwise-identical).
pub fn bcsr_spmm_par(m: &BlockedCsr, b: &[f32], f: usize, out: &mut [f32], threads: usize) {
    assert_eq!(b.len(), m.n_cols * f);
    assert_eq!(out.len(), m.n_rows * f);
    let lvl = simd::level();
    let chunks = balance_rows(|i| m.row_range(i).len(), m.n_rows, threads.max(1));
    let slices = split_output(out, &chunks, f);
    let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = chunks
        .into_iter()
        .zip(slices)
        .map(|(range, slice)| {
            Box::new(move || {
                slice.fill(0.0);
                bcsr_rows(lvl, m, b, f, range, slice);
            }) as Box<dyn FnOnce() + Send + '_>
        })
        .collect();
    crate::exec::global_pool().run(tasks);
}

/// Dense-tile SpMM at the detected SIMD level.
pub fn dense_spmm(t: &DenseTile, b: &[f32], f: usize, out: &mut [f32]) {
    dense_spmm_at(simd::level(), t, b, f, out)
}

/// [`dense_spmm`] pinned to an explicit SIMD level (tests/benches).
pub fn dense_spmm_at(lvl: SimdLevel, t: &DenseTile, b: &[f32], f: usize, out: &mut [f32]) {
    assert_eq!(b.len(), t.n_cols * f);
    assert_eq!(out.len(), t.n_rows * f);
    out.fill(0.0);
    dense_rows(lvl, t, b, f, 0..t.n_rows, out);
}

/// Row-range worker: fixed-pitch unit-stride rows, feature-blocked like
/// the ELL kernel, each row's full edge list in canonical order.
fn dense_rows(
    lvl: SimdLevel,
    t: &DenseTile,
    b: &[f32],
    f: usize,
    rows: std::ops::Range<usize>,
    out: &mut [f32],
) {
    let kb = simd::feat_block(t.n_cols, f).max(1);
    let p = t.pitch;
    let mut k0 = 0usize;
    while k0 < f {
        let kw = kb.min(f - k0);
        for i in rows.clone() {
            let n = t.row_nnz(i);
            if n == 0 {
                continue;
            }
            simd::prefetch_read(&t.val, (i + 1) * p);
            simd::prefetch_read(&t.col, (i + 1) * p);
            let oi = i - rows.start;
            simd::ell_row(
                lvl,
                &t.val[i * p..i * p + n],
                &t.col[i * p..i * p + n],
                b,
                f,
                k0,
                &mut out[oi * f + k0..oi * f + k0 + kw],
            );
        }
        k0 += kw;
    }
}

/// Parallel [`dense_spmm`].
pub fn dense_spmm_par(t: &DenseTile, b: &[f32], f: usize, out: &mut [f32], threads: usize) {
    assert_eq!(b.len(), t.n_cols * f);
    assert_eq!(out.len(), t.n_rows * f);
    let lvl = simd::level();
    let chunks = balance_rows(|i| t.row_nnz(i), t.n_rows, threads.max(1));
    let slices = split_output(out, &chunks, f);
    let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = chunks
        .into_iter()
        .zip(slices)
        .map(|(range, slice)| {
            Box::new(move || {
                slice.fill(0.0);
                dense_rows(lvl, t, b, f, range, slice);
            }) as Box<dyn FnOnce() + Send + '_>
        })
        .collect();
    crate::exec::global_pool().run(tasks);
}

// ---------------------------------------------------------------------------
// INT8-compute kernels
// ---------------------------------------------------------------------------

/// Blocked-CSR SpMM in the quantized domain. `aq.qa` is in CSR nnz
/// order (an [`AdjQuant::from_csr`] of the source graph), exactly as
/// the CSR i8 kernel consumes it — blocked grouping never reorders
/// edges.
pub fn bcsr_spmm_i8(m: &BlockedCsr, aq: &AdjQuant, qb: &[u8], f: usize, out: &mut [f32]) {
    bcsr_spmm_i8_at(simd::level(), m, aq, qb, f, out)
}

/// [`bcsr_spmm_i8`] pinned to an explicit SIMD level.
pub fn bcsr_spmm_i8_at(
    lvl: SimdLevel,
    m: &BlockedCsr,
    aq: &AdjQuant,
    qb: &[u8],
    f: usize,
    out: &mut [f32],
) {
    assert_eq!(qb.len(), m.n_cols * f);
    assert_eq!(out.len(), m.n_rows * f);
    assert_eq!(aq.qa.len(), m.val.len());
    bcsr_i8_rows(lvl, m, aq, qb, f, 0..m.n_rows, out);
}

fn bcsr_i8_rows(
    lvl: SimdLevel,
    m: &BlockedCsr,
    aq: &AdjQuant,
    qb: &[u8],
    f: usize,
    rows: std::ops::Range<usize>,
    out: &mut [f32],
) {
    let mut acc = vec![0i32; f];
    for (oi, i) in rows.enumerate() {
        let r = m.row_range(i);
        i8_row_rescale(
            lvl,
            &aq.qa[r.clone()],
            &m.col_ind[r],
            qb,
            f,
            aq.row_scale[i],
            aq.row_base[i],
            &mut acc,
            &mut out[oi * f..(oi + 1) * f],
        );
    }
}

/// Parallel [`bcsr_spmm_i8`].
pub fn bcsr_spmm_i8_par(
    m: &BlockedCsr,
    aq: &AdjQuant,
    qb: &[u8],
    f: usize,
    out: &mut [f32],
    threads: usize,
) {
    assert_eq!(qb.len(), m.n_cols * f);
    assert_eq!(out.len(), m.n_rows * f);
    assert_eq!(aq.qa.len(), m.val.len());
    let lvl = simd::level();
    let chunks = balance_rows(|i| m.row_range(i).len(), m.n_rows, threads.max(1));
    let slices = split_output(out, &chunks, f);
    let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = chunks
        .into_iter()
        .zip(slices)
        .map(|(range, slice)| {
            Box::new(move || {
                bcsr_i8_rows(lvl, m, aq, qb, f, range, slice);
            }) as Box<dyn FnOnce() + Send + '_>
        })
        .collect();
    crate::exec::global_pool().run(tasks);
}

/// Dense-tile SpMM in the quantized domain. `aq.qa` is in CSR nnz
/// order; the tile's `edge_off` (the CSR row pointer) addresses each
/// row's coefficient run, so the same [`AdjQuant`] serves CSR, blocked,
/// and dense execution of one shard.
pub fn dense_spmm_i8(t: &DenseTile, aq: &AdjQuant, qb: &[u8], f: usize, out: &mut [f32]) {
    dense_spmm_i8_at(simd::level(), t, aq, qb, f, out)
}

/// [`dense_spmm_i8`] pinned to an explicit SIMD level.
pub fn dense_spmm_i8_at(
    lvl: SimdLevel,
    t: &DenseTile,
    aq: &AdjQuant,
    qb: &[u8],
    f: usize,
    out: &mut [f32],
) {
    assert_eq!(qb.len(), t.n_cols * f);
    assert_eq!(out.len(), t.n_rows * f);
    assert_eq!(aq.qa.len(), t.nnz());
    dense_i8_rows(lvl, t, aq, qb, f, 0..t.n_rows, out);
}

fn dense_i8_rows(
    lvl: SimdLevel,
    t: &DenseTile,
    aq: &AdjQuant,
    qb: &[u8],
    f: usize,
    rows: std::ops::Range<usize>,
    out: &mut [f32],
) {
    let p = t.pitch;
    let mut acc = vec![0i32; f];
    for (oi, i) in rows.enumerate() {
        let lo = t.edge_off[i] as usize;
        let n = t.row_nnz(i);
        simd::prefetch_read(&t.col, (i + 1) * p);
        i8_row_rescale(
            lvl,
            &aq.qa[lo..lo + n],
            &t.col[i * p..i * p + n],
            qb,
            f,
            aq.row_scale[i],
            aq.row_base[i],
            &mut acc,
            &mut out[oi * f..(oi + 1) * f],
        );
    }
}

/// Parallel [`dense_spmm_i8`].
pub fn dense_spmm_i8_par(
    t: &DenseTile,
    aq: &AdjQuant,
    qb: &[u8],
    f: usize,
    out: &mut [f32],
    threads: usize,
) {
    assert_eq!(qb.len(), t.n_cols * f);
    assert_eq!(out.len(), t.n_rows * f);
    assert_eq!(aq.qa.len(), t.nnz());
    let lvl = simd::level();
    let chunks = balance_rows(|i| t.row_nnz(i), t.n_rows, threads.max(1));
    let slices = split_output(out, &chunks, f);
    let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = chunks
        .into_iter()
        .zip(slices)
        .map(|(range, slice)| {
            Box::new(move || {
                dense_i8_rows(lvl, t, aq, qb, f, range, slice);
            }) as Box<dyn FnOnce() + Send + '_>
        })
        .collect();
    crate::exec::global_pool().run(tasks);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spmm::testutil::random_graph_and_features;
    use crate::spmm::{csr_naive, csr_spmm_i8};

    #[test]
    fn bcsr_matches_naive_bitwise_across_block_heights() {
        let (g, b) = random_graph_and_features(220, 18.0, 17, 41);
        let mut want = vec![0.0f32; g.n_rows * 17];
        csr_naive(&g, &b, 17, &mut want);
        for h in [1usize, 3, 64, 1000] {
            let m = BlockedCsr::from_csr(&g, h);
            let mut got = vec![9.0f32; g.n_rows * 17];
            bcsr_spmm(&m, &b, 17, &mut got);
            assert_eq!(want, got, "block_rows={h}");
            let mut par = vec![0.0f32; g.n_rows * 17];
            bcsr_spmm_par(&m, &b, 17, &mut par, 4);
            assert_eq!(want, par, "block_rows={h} (par)");
        }
    }

    #[test]
    fn dense_matches_naive_bitwise() {
        let (g, b) = random_graph_and_features(150, 30.0, 9, 42);
        let mut want = vec![0.0f32; g.n_rows * 9];
        csr_naive(&g, &b, 9, &mut want);
        let t = DenseTile::from_csr(&g);
        let mut got = vec![5.0f32; g.n_rows * 9];
        dense_spmm(&t, &b, 9, &mut got);
        assert_eq!(want, got);
        let mut par = vec![0.0f32; g.n_rows * 9];
        dense_spmm_par(&t, &b, 9, &mut par, 3);
        assert_eq!(want, par);
    }

    #[test]
    fn i8_formats_match_csr_i8_bitwise() {
        use crate::quant::ChunkedParams;
        let (g, b) = random_graph_and_features(160, 12.0, 11, 43);
        let params = ChunkedParams::of_rows(&b, 160, 11, 40);
        let qb = params.quantize_rows(&b, 11);
        let aq = AdjQuant::from_csr(&g, &params);
        let mut want = vec![0.0f32; g.n_rows * 11];
        csr_spmm_i8(&g, &aq, &qb, 11, &mut want);

        let m = BlockedCsr::from_csr(&g, 16);
        let mut got = vec![0.0f32; g.n_rows * 11];
        bcsr_spmm_i8(&m, &aq, &qb, 11, &mut got);
        assert_eq!(want, got);
        bcsr_spmm_i8_par(&m, &aq, &qb, 11, &mut got, 5);
        assert_eq!(want, got);

        let t = DenseTile::from_csr(&g);
        dense_spmm_i8(&t, &aq, &qb, 11, &mut got);
        assert_eq!(want, got);
        dense_spmm_i8_par(&t, &aq, &qb, 11, &mut got, 3);
        assert_eq!(want, got);
    }

    #[test]
    fn empty_graph_and_empty_rows() {
        let g = Csr::new(0, 4, vec![0], vec![], vec![]).unwrap();
        let b = vec![1.0f32; 4 * 3];
        let m = BlockedCsr::from_csr(&g, 8);
        let t = DenseTile::from_csr(&g);
        let mut out = Vec::new();
        bcsr_spmm(&m, &b, 3, &mut out);
        dense_spmm(&t, &b, 3, &mut out);
        assert_eq!(m.to_csr(), g);
        assert_eq!(t.to_csr(), g);

        let g = Csr::new(3, 3, vec![0, 0, 1, 1], vec![2], vec![5.0]).unwrap();
        let b = vec![1.0f32; 9];
        let mut want = vec![0.0f32; 9];
        csr_naive(&g, &b, 3, &mut want);
        let mut got = vec![7.0f32; 9];
        bcsr_spmm(&BlockedCsr::from_csr(&g, 2), &b, 3, &mut got);
        assert_eq!(want, got);
        let mut got = vec![7.0f32; 9];
        dense_spmm(&DenseTile::from_csr(&g), &b, 3, &mut got);
        assert_eq!(want, got);
    }

    #[test]
    fn viability_guard_tracks_padding() {
        let (g, _) = random_graph_and_features(100, 8.0, 4, 44);
        // Power-law graphs have long tails: generous slack passes,
        // slack 0 never does (padding is at least the stored entries).
        assert!(dense_tile_viable(&g, 1000));
        assert!(!dense_tile_viable(&g, 0));
        let t = DenseTile::from_csr(&g);
        assert_eq!(t.pitch % 8, 0);
        assert!(t.pitch >= g.max_degree().max(1));
    }
}
