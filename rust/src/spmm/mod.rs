//! CPU SpMM kernels — the baseline zoo of the paper's evaluation, rebuilt
//! on this substrate (DESIGN.md §4):
//!
//! * [`csr_naive`]    — straightforward CSR SpMM; plays **cuSPARSE** (the
//!   vendor kernel: exact, no locality tricks beyond row order).
//! * [`csr_rowcache`] — **GE-SpMM** analog: Coalesced Row Caching (stage
//!   the row's (val, col) segment into a stack tile = "shared memory")
//!   plus Coarse-grained Warp Merging (process feature columns in wide
//!   register blocks).
//! * [`ell_spmm`]     — the sampled-matrix multiply (AES/AFS/SFS plans),
//!   Alg. 1 lines 16–19 on the host.
//! * [`ell_spmm_i8`] / [`csr_spmm_i8`] — true INT8 compute: `i8×u8→i32`
//!   accumulation over an [`AdjQuant`] requantized adjacency, one
//!   rescale per row (Eq. 1/2 in the quantized domain).
//! * `formats`        — the tuned dispatcher's format zoo:
//!   [`BlockedCsr`] (fixed-height row blocks over verbatim CSR arrays)
//!   and [`DenseTile`] (fixed-pitch row slabs for near-dense shards),
//!   each with fp32 + i8 entry points bitwise-equal to the CSR path
//!   (docs/dispatch.md).
//! * `simd`           — runtime AVX2/NEON dispatch, cache-profile tile
//!   tuning, and the bitwise-equality contract every arm obeys
//!   (docs/simd.md).
//! * `segmented`      — segmented row reductions for the model zoo: the
//!   GAT attention softmax (per-edge logits → stable row softmax → α)
//!   and the GraphSAGE max-pool, over CSR and ELL operands
//!   (docs/models.md).
//! * `threaded`       — row-partitioned multi-thread wrappers over any of
//!   the above (std::thread scoped; the offline registry has no rayon).
//!
//! All kernels compute `C = A × B` with `B` row-major `[n, f]` (fp32, or
//! u8 codes for the INT8-compute kernels).

mod csr;
mod ell;
mod formats;
mod int8;
pub mod segmented;
pub mod simd;
mod threaded;

pub use csr::{csr_naive, csr_rowcache, csr_rowcache_at, TILE as ROWCACHE_TILE};
pub use ell::{ell_spmm, ell_spmm_at, ell_spmm_mean};
pub use segmented::{
    attention_scores, attention_scores_par, gat_alpha_csr, gat_alpha_csr_par, gat_alpha_ell,
    gat_alpha_ell_par, leaky_relu, row_softmax, segmented_max_csr, segmented_max_csr_par,
    segmented_max_ell, segmented_max_ell_par, LEAKY_RELU_SLOPE,
};
pub use formats::{
    bcsr_spmm, bcsr_spmm_at, bcsr_spmm_i8, bcsr_spmm_i8_at, bcsr_spmm_i8_par, bcsr_spmm_par,
    dense_spmm, dense_spmm_at, dense_spmm_i8, dense_spmm_i8_at, dense_spmm_i8_par, dense_spmm_par,
    dense_tile_viable, BlockedCsr, DenseTile, BCSR_BLOCK_ROWS,
};
pub use int8::{
    csr_spmm_i8, csr_spmm_i8_at, csr_spmm_i8_par, ell_spmm_i8, ell_spmm_i8_at, ell_spmm_i8_par,
    AdjQuant, I8_FLUSH_EDGES,
};
pub use threaded::{csr_naive_par, ell_spmm_par};

/// Flop count of an exact fp32 SpMM (2 flops per nnz per feature column).
pub fn spmm_flops(nnz: usize, feat_dim: usize) -> usize {
    2 * nnz * feat_dim
}

/// Fp32-flop *equivalents* of an `i8×u8→i32` SpMM over the same nnz —
/// integer MACs retire roughly twice as cheap per element on the vector
/// units (wider lanes, no FP latency chains), so cost-based dispatch
/// thresholds ([`crate::exec::PAR_MIN_FLOPS`]) must compare like units
/// rather than assume fp32 cost per nnz.
pub fn spmm_i8_flops(nnz: usize, feat_dim: usize) -> usize {
    spmm_flops(nnz, feat_dim) / 2
}

#[cfg(test)]
pub(crate) mod testutil {
    use crate::gen;
    use crate::graph::Csr;
    use crate::rng::Pcg32;

    /// Dense reference multiply for cross-checking every kernel.
    pub fn dense_ref(csr: &Csr, b: &[f32], f: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; csr.n_rows * f];
        for i in 0..csr.n_rows {
            for e in csr.row_range(i) {
                let c = csr.col_ind[e] as usize;
                let v = csr.val[e];
                for k in 0..f {
                    out[i * f + k] += v * b[c * f + k];
                }
            }
        }
        out
    }

    pub fn random_graph_and_features(
        n: usize,
        deg: f64,
        f: usize,
        seed: u64,
    ) -> (Csr, Vec<f32>) {
        let mut rng = Pcg32::new(seed);
        let mut g = gen::chung_lu(n, deg, 1.9, &mut rng);
        for v in g.val.iter_mut() {
            *v = rng.f32() - 0.5;
        }
        let b: Vec<f32> = (0..n * f).map(|_| rng.f32() - 0.5).collect();
        (g, b)
    }

    pub fn assert_close(a: &[f32], b: &[f32], tol: f32) {
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            assert!(
                (x - y).abs() <= tol * (1.0 + x.abs().max(y.abs())),
                "idx {i}: {x} vs {y}"
            );
        }
    }
}
