//! Deterministic PRNGs — the offline registry has no `rand`, so we ship
//! PCG32 (O'Neill 2014) seeded through SplitMix64. Used by the synthetic
//! workload generators, the property-test harness, and the benchmark
//! request streams; everything in this repo is reproducible from a u64
//! seed.

/// SplitMix64 — used to expand a single u64 seed into stream parameters.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// PCG32 (XSH-RR 64/32): small, fast, statistically solid.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    /// Seed via SplitMix64 so nearby seeds give unrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let state = sm.next_u64();
        let inc = sm.next_u64() | 1;
        let mut rng = Self { state: 0, inc };
        rng.state = state.wrapping_add(inc);
        rng.next_u32();
        rng
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, bound)` (Lemire's multiply-shift with rejection).
    pub fn below(&mut self, bound: u32) -> u32 {
        assert!(bound > 0, "below(0)");
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let r = self.next_u32();
            let m = (r as u64) * (bound as u64);
            if (m as u32) >= threshold {
                return (m >> 32) as u32;
            }
        }
    }

    pub fn usize_below(&mut self, bound: usize) -> usize {
        debug_assert!(bound <= u32::MAX as usize);
        self.below(bound as u32) as usize
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1 << 24) as f32)
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal via Box–Muller (one value per call; fine for gen).
    pub fn normal(&mut self) -> f64 {
        let u1 = (self.f64()).max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Pcg32::new(42);
        let mut b = Pcg32::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
        let mut c = Pcg32::new(43);
        let same = (0..100).filter(|_| a.next_u32() == c.next_u32()).count();
        assert!(same < 5, "different seeds should diverge");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Pcg32::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.below(10);
            assert!(v < 10);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn f64_unit_interval_mean() {
        let mut rng = Pcg32::new(1);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| rng.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean} too far from 0.5");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg32::new(2);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut rng = Pcg32::new(3);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>(), "shuffle should move things");
    }
}
