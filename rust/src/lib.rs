//! AES-SpMM reproduction — Layer-3 coordinator and substrates.
//!
//! Reproduces "AES-SpMM: Balancing Accuracy and Speed by Adaptive Edge
//! Sampling Strategy to Accelerate SpMM in GNNs" (Song et al., 2025) as a
//! three-layer rust + JAX + Pallas stack:
//!
//! * **L1** (build time): Pallas kernels implementing the paper's adaptive
//!   edge sampling (Table 1 + Eq. 3) and the sampled SpMM (Algorithm 1).
//! * **L2** (build time): GCN / GraphSAGE forward passes in JAX, lowered
//!   once to HLO text per (model, dataset, W).
//! * **L3** (this crate): the GNN inference serving system — graph store,
//!   fp32 + INT8 feature store, sampling planner, dynamic request batcher,
//!   PJRT executor pool, metrics, experiment harness, CLI.
//!
//! Python never runs on the request path: the binary is self-contained
//! once `make artifacts` has produced `artifacts/*.hlo.txt` + `*.nbt`.
//!
//! Module map (DESIGN.md §5):
//!
//! | module        | role                                                  |
//! |---------------|-------------------------------------------------------|
//! | [`tensor`]    | `.nbt` named-binary-tensor container, dtypes          |
//! | [`rng`]       | PCG32 / SplitMix64 (offline registry has no `rand`)   |
//! | [`graph`]     | CSR / ELL structures, validation, degree statistics, shard partitioner |
//! | [`gen`]       | synthetic graph generators (Chung-Lu, DC-SBM, RMAT)   |
//! | [`sampling`]  | the paper's strategy table + hash, ELL planners, CDFs |
//! | [`quant`]     | INT8 quantization (per-chunk), mmap feature store, streamed row-block handles |
//! | [`spmm`]      | CPU SpMM kernels (cuSPARSE / GE-SpMM analogs, ELL)    |
//! | [`exec`]      | kernel dispatch, persistent pool, plan cache, async prefetch, sharded plans |
//! | [`runtime`]   | PJRT engine: artifact registry, executables, literals |
//! | [`coordinator`]| request router, dynamic batcher, worker pool, metrics, TCP wire front-end |
//! | [`loadgen`]   | closed/open-loop load generation against a wire server (BENCH_serving.json) |
//! | [`eval`]      | accuracy conformance: exact oracle, budget table, grid harness |
//! | [`experiments`]| one runner per paper figure/table                    |
//! | [`bench`]     | micro-bench harness (no criterion offline)            |
//! | [`util`]      | flat-JSON parsing/emission, timing helpers            |

pub mod bench;
pub mod coordinator;
pub mod eval;
pub mod exec;
pub mod experiments;
pub mod gen;
pub mod graph;
pub mod loadgen;
pub mod quant;
pub mod rng;
pub mod runtime;
pub mod sampling;
pub mod spmm;
pub mod tensor;
pub mod util;
