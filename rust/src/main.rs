//! `repro` — the AES-SpMM leader binary.
//!
//! Subcommands (hand-rolled CLI; no clap in the offline registry):
//!
//! ```text
//! repro inspect   [--artifacts DIR]                         dataset/artifact summary
//! repro infer     --model M --dataset D [--width W]
//!                 [--strategy afs|sfs|aes] [--fp32]         one forward pass + accuracy
//! repro serve     [--requests N] [--workers K]              run the coordinator demo load
//! repro serve     --listen ADDR [--eval-data DIR]           TCP wire front-end (docs/serving.md)
//! repro shard-server --listen ADDR [--eval-data DIR]        shard worker (serve --listen + sharding on)
//! repro router    --listen ADDR --workers A1,A2,...         scatter/gather router over shard workers
//! repro loadgen   --addr HOST:PORT [--scenario FILE]        closed-loop load harness
//! repro mutate    --dataset D --edges FILE                  apply a live edge delta, re-serve
//! repro experiment <fig2|fig3|fig5|fig6|fig7|tab1|tab3|all> [--quick]
//! repro eval      [--json [PATH]] [--dir DIR] [--quick]     accuracy conformance grid
//! repro tune      [--quick] [--out PATH]                    bench + write the dispatch cost model
//! repro tune      --validate PATH                           load-check an existing cost model
//! repro gen-data  --nodes N --avg-deg D [--gamma G]         rust-side synthetic graph stats
//! ```
//!
//! Serving precision defaults to INT8 (the paper's quantized path);
//! `--fp32` opts into the full-precision baseline.

use std::collections::HashMap;
use std::process::ExitCode;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use aes_spmm::coordinator::{Coordinator, CoordinatorConfig, ModelStore, RouteKey};
use aes_spmm::experiments::{self, ExpContext};
use aes_spmm::gen;
use aes_spmm::graph::DegreeStats;
use aes_spmm::quant::Precision;
use aes_spmm::rng::Pcg32;
use aes_spmm::runtime::{accuracy, run_forward, Dataset, Engine, ForwardRequest, Weights};
use aes_spmm::sampling::Strategy;

fn main() -> ExitCode {
    match run() {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

/// Minimal flag parser: positionals + `--key value` + boolean `--key`.
struct Args {
    positional: Vec<String>,
    flags: HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Args {
        let mut positional = Vec::new();
        let mut flags = HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    flags.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    flags.insert(key.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                positional.push(a.clone());
                i += 1;
            }
        }
        Args { positional, flags }
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} must be an integer")),
            None => Ok(default),
        }
    }
}

const USAGE: &str = "\
repro — AES-SpMM reproduction (rust + JAX + Pallas, AOT via PJRT)

USAGE:
  repro inspect    [--artifacts DIR]
  repro infer      --model gcn|sage --dataset NAME [--width W] [--strategy afs|sfs|aes] [--fp32] [--artifacts DIR]
  repro serve      [--requests N] [--workers K] [--queue Q] [--batch B] [--prefetch P]
                   [--host] [--models M1,M2] [--shards N] [--shard-budget MIB] [--artifacts DIR]
  repro serve      --listen ADDR [--eval-data DIR] [--port-file PATH] [--high-water H]
                   [--max-seconds S] [--workers K] [--queue Q] [--batch B] [--prefetch P]
                   [--host] [--models M1,M2] [--shards N] [--shard-budget MIB] [--artifacts DIR]
  repro shard-server --listen ADDR [--eval-data DIR] [--port-file PATH] [--high-water H]
                   [--max-seconds S] [--shards N] [--shard-budget MIB] [serve --listen flags]
  repro router     --listen ADDR --workers HOST:PORT,HOST:PORT,... [--port-file PATH]
                   [--high-water H] [--max-seconds S]
  repro loadgen    --addr HOST:PORT [--scenario FILE] [--quick] [--json [PATH]]
                   [--prefix NAME] [--append]
  repro mutate     --dataset NAME --edges FILE [--width W] [--strategy afs|sfs|aes]
                   [--shards N] [--shard-budget MIB] [--artifacts DIR]
  repro experiment fig2|fig3|fig5|fig6|fig7|tab1|tab3|all [--quick] [--artifacts DIR]
  repro eval       [--json [PATH]] [--dir DIR] [--quick]
  repro tune       [--quick] [--out PATH]
  repro tune       --validate PATH
  repro gen-data   [--nodes N] [--avg-deg D] [--gamma G] [--seed S]

Serving precision defaults to INT8 (--fp32 opts into the baseline;
--precision f32|u8-device|u8-host|i8-compute picks one explicitly on
`infer`; i8-compute aggregates the codes in integer arithmetic on the
host backend — docs/simd.md).
`eval` needs no artifacts: it runs the accuracy-conformance grid
(strategy x width x precision x shards) on seeded synthetic datasets
through the host serving path, scores every configuration against the
exact oracle (docs/accuracy.md), and with --json writes ACC_eval.json
(default path) for the tools/acc_diff.rs CI gate. Exits nonzero on any
budget violation.
--host serves on the rust substrate (no PJRT); --shards/--shard-budget
row-shard host aggregation into working-set-budgeted GraphShards with
per-shard sampling + kernel dispatch (see docs/sharding.md).
`tune` benches every admissible kernel x format x precision cell over a
grid of synthetic shard profiles on this machine and writes a
schema-versioned cost model (default COST_spmm.json). `infer`, `serve`,
`mutate`, and `eval` install one via --cost-model PATH (or the
AES_SPMM_COST_MODEL env var): per-shard dispatch then follows the
measured table, falling back to the built-in heuristics for unmeasured
profiles — and entirely, with a warning, when the file is missing,
corrupt, or schema-stale (docs/dispatch.md).
`serve --listen` speaks the length-prefixed TCP wire protocol
(docs/serving.md): infer/logits/mutate plus the status/metrics/routes
ops surface, with load shedding past --high-water in-flight requests.
--models picks the served model roster (comma-separated; docs/models.md
— the host backend runs any model as a layer-graph IR program, so
--eval-data defaults to the full zoo gcn,sage,gat; artifact-backed
serving defaults to gcn,sage, the models `make artifacts` compiles).
--eval-data DIR serves the seeded conformance datasets on the host
backend (no artifacts needed — what CI does); --port-file writes the
bound address (bind :0 for an ephemeral port); --max-seconds self-exits
(0 = run forever). `loadgen` offers power-law route traffic from
--scenario FILE (or the built-in default; --quick shrinks it), prints
per-route p50/p99/p999 + throughput + shed counts, and with --json
writes BENCH_serving.json (default path) for the tools/bench_diff.rs
serving gate; --prefix NAME prefixes every workload name and --append
merges the new workloads into an existing --json file instead of
overwriting it (how CI lands the sharded-router pass next to the
single-server one).
`shard-server` is `serve --listen` with row-sharding on by default
(3 shards unless --shards/--shard-budget say otherwise): a worker
process that owns shard row ranges behind a `router`. `router` serves
the ordinary client protocol by scatter/gathering shard_logits/
shard_infer over --workers, broadcasts mutations to every worker as an
epoch-tagged replication log (read-your-writes: the client ack waits
for every live worker), and on worker death re-places the dead
worker's shards onto survivors and replays the log from their epoch
watermarks (docs/serving.md).
`mutate` applies a live edge delta (insert/delete/reweight lines, see
docs/mutation.md for the file format) through the serving coordinator:
the graph advances one epoch, only the shard units of touched shards
re-sample, and the post-delta forward is checked bitwise against a cold
coordinator built directly on the mutated graph.
Run `make artifacts` first to produce the AOT artifacts.";

fn run() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        println!("{USAGE}");
        return Ok(());
    }
    let cmd = argv[0].clone();
    let mut args = Args::parse(&argv[1..]);
    let artifacts = args.get_or("artifacts", "artifacts");
    match cmd.as_str() {
        "inspect" => cmd_inspect(&artifacts),
        "infer" => cmd_infer(&artifacts, &args),
        "serve" => cmd_serve(&artifacts, &args),
        "shard-server" => cmd_shard_server(&artifacts, &mut args),
        "router" => cmd_router(&args),
        "loadgen" => cmd_loadgen(&args),
        "mutate" => cmd_mutate(&artifacts, &args),
        "experiment" => cmd_experiment(&artifacts, &args),
        "eval" => cmd_eval(&args),
        "tune" => cmd_tune(&args),
        "gen-data" => cmd_gen_data(&args),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command {other:?}\n{USAGE}"),
    }
}

/// Parse `--models M1,M2` into the serving roster, defaulting to
/// `default` when the flag is absent. Every name must be a model the
/// layer-graph IR knows (`runtime::KNOWN_MODELS`).
fn models_flag(args: &Args, default: &[&str]) -> Result<Vec<String>> {
    let models: Vec<String> = match args.get("models") {
        Some(list) => list
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect(),
        None => default.iter().map(|s| s.to_string()).collect(),
    };
    if models.is_empty() {
        bail!("--models needs at least one model");
    }
    for m in &models {
        if !aes_spmm::runtime::KNOWN_MODELS.contains(&m.as_str()) {
            bail!(
                "--models: unknown model {m:?} (known: {})",
                aes_spmm::runtime::KNOWN_MODELS.join("|")
            );
        }
    }
    Ok(models)
}

/// Install a learned dispatch cost model for this process when asked
/// via `--cost-model PATH` or the `AES_SPMM_COST_MODEL` env var (flag
/// wins). An invalid, stale, or missing profile warns and leaves the
/// heuristics in charge — it never fails the command.
fn maybe_install_cost_model(args: &Args) {
    let path = args
        .get("cost-model")
        .map(str::to_string)
        .or_else(|| std::env::var("AES_SPMM_COST_MODEL").ok());
    if let Some(p) = path {
        if aes_spmm::exec::install_cost_model_from(std::path::Path::new(&p)) {
            let fp = aes_spmm::exec::installed_fingerprint();
            println!("cost model: {p} installed (fingerprint {fp:#018x})");
        }
    }
}

/// `repro tune` — bench every admissible kernel×format×precision cell
/// over synthetic shard profiles on this machine and write the
/// schema-versioned cost model; `--validate PATH` load-checks an
/// existing profile instead (nonzero exit on a stale/corrupt one).
fn cmd_tune(args: &Args) -> Result<()> {
    use aes_spmm::exec::{run_tune, CostModel, TuneOptions};
    if let Some(path) = args.get("validate") {
        let model = CostModel::load(std::path::Path::new(path))?;
        println!(
            "{path}: valid cost model (version {}, {} cells, fingerprint {:#018x})",
            aes_spmm::exec::COST_MODEL_VERSION,
            model.len(),
            model.fingerprint()
        );
        return Ok(());
    }
    let out = args.get_or("out", "COST_spmm.json");
    let opts = TuneOptions { quick: args.has("quick") };
    let grid = if opts.quick { "quick" } else { "full" };
    println!("tuning kernel/format/precision dispatch on this machine ({grid} grid)");
    let model = run_tune(&opts);
    std::fs::write(&out, model.to_json().to_string())
        .with_context(|| format!("writing {out}"))?;
    println!("wrote {out} (fingerprint {:#018x})", model.fingerprint());
    Ok(())
}

fn cmd_inspect(artifacts: &str) -> Result<()> {
    let engine = Engine::new(artifacts)?;
    let m = engine.manifest();
    println!("platform: {}", engine.platform());
    println!("artifacts dir: {}", m.dir.display());
    println!(
        "\n{:<10} {:>7} {:>9} {:>6} {:>8} {:>9} {:>8}  ideal acc (gcn/sage)",
        "dataset", "nodes", "edges", "feats", "classes", "avg deg", "max deg"
    );
    for name in m.dataset_names() {
        let meta = m.dataset(&name)?;
        let ds = Dataset::load(&m.dir, &name)?;
        let stats = DegreeStats::of(&ds.csr_gcn);
        println!(
            "{:<10} {:>7} {:>9} {:>6} {:>8} {:>9.1} {:>8}  {:.4}/{:.4}",
            name,
            meta.n,
            meta.nnz,
            meta.feats,
            meta.classes,
            stats.mean,
            stats.max,
            meta.ideal_acc.get("gcn").unwrap_or(&f64::NAN),
            meta.ideal_acc.get("sage").unwrap_or(&f64::NAN),
        );
    }
    println!("\ncompiled artifact inventory: {} modules", m.artifacts.len());
    let mut kinds: HashMap<&'static str, usize> = HashMap::new();
    for a in m.artifacts.values() {
        *kinds
            .entry(match a.kind {
                aes_spmm::runtime::ArtifactKind::Baseline => "baseline",
                aes_spmm::runtime::ArtifactKind::Sampled => "sampled",
                aes_spmm::runtime::ArtifactKind::Quantized => "quantized",
            })
            .or_insert(0) += 1;
    }
    for (k, v) in kinds {
        println!("  {k}: {v}");
    }
    Ok(())
}

fn cmd_infer(artifacts: &str, args: &Args) -> Result<()> {
    maybe_install_cost_model(args);
    let model = args.get("model").context("--model required")?.to_string();
    let dataset = args.get("dataset").context("--dataset required")?.to_string();
    let width = args.get("width").map(|w| w.parse::<usize>()).transpose()?;
    let strategy = Strategy::from_name(&args.get_or("strategy", "aes"))
        .context("--strategy must be afs|sfs|aes")?;
    if args.has("fp32") && args.has("quant") {
        bail!("--fp32 and --quant are mutually exclusive");
    }
    // INT8 is the serving default; --fp32 opts into the baseline
    // (--quant kept for backward compatibility — it is now the default)
    // and --precision picks any representation by its route-key label.
    let precision = match args.get("precision") {
        Some(p) => {
            if args.has("fp32") || args.has("quant") {
                bail!("--precision conflicts with --fp32/--quant");
            }
            Precision::from_name(p)
                .with_context(|| {
                    format!("--precision must be f32|u8-device|u8-host|i8-compute, got {p:?}")
                })?
        }
        None if args.has("fp32") => Precision::F32,
        None => Precision::default(),
    };

    let engine = Engine::new(artifacts)?;
    let ds = Dataset::load(artifacts, &dataset)?;
    let weights = Weights::load(artifacts, &model, &dataset)?;
    let req = ForwardRequest { model, dataset, width, strategy, precision };
    println!("artifact: {}", req.artifact_name());
    let result = run_forward(&engine, &ds, &weights, &req, None)?;
    let acc = accuracy(&ds, &result.logits)?;
    println!(
        "accuracy: {:.4} (ideal {:.4}, delta {:+.2}pp)",
        acc,
        weights.ideal_acc,
        (acc - weights.ideal_acc as f64) * 100.0
    );
    println!(
        "timing: transfer {:?}  execute {:?}  fetch {:?}",
        result.stats.transfer, result.stats.execute, result.stats.fetch
    );
    Ok(())
}

fn cmd_serve(artifacts: &str, args: &Args) -> Result<()> {
    if args.has("listen") {
        return cmd_serve_listen(artifacts, args);
    }
    maybe_install_cost_model(args);
    let n_requests = args.usize_or("requests", 200)?;
    let workers = args.usize_or("workers", 2)?;
    let queue = args.usize_or("queue", 1024)?;
    let batch = args.usize_or("batch", 32)?;
    let prefetch = args.usize_or("prefetch", 1)?;
    // --shards / --shard-budget (MiB) turn on row-sharded host plans.
    let sharding = if args.has("shards") || args.has("shard-budget") {
        Some(aes_spmm::graph::ShardSpec {
            shards: args
                .get("shards")
                .map(|s| s.parse().context("--shards must be an integer"))
                .transpose()?,
            budget_bytes: args.usize_or("shard-budget", 32)? << 20,
        })
    } else {
        None
    };

    let engine = Arc::new(Engine::new(artifacts)?);
    let datasets = engine.manifest().dataset_names();
    // Both substrates serve the artifact-compiled models (the host
    // backend runs them as IR programs); --models narrows or widens the
    // roster when the artifacts dir carries more.
    let models = models_flag(args, &["gcn", "sage"])?;
    let store = Arc::new(ModelStore::load(artifacts, &datasets, &models)?);

    let cfg = CoordinatorConfig {
        workers,
        queue_depth: queue,
        batcher: aes_spmm::coordinator::BatcherConfig {
            max_batch: batch,
            max_delay: std::time::Duration::from_millis(2),
        },
        prefetch_workers: prefetch,
        sharding,
        ..CoordinatorConfig::default()
    };
    let coord = if args.has("host") {
        // The rust substrate: sharding applies here (host aggregation).
        Coordinator::start_with(aes_spmm::runtime::Backend::Host, store.clone(), cfg)
    } else {
        Coordinator::start(engine.clone(), store.clone(), cfg)
    };

    // Synthetic request mix: random (dataset, width, strategy, precision).
    let mut rng = Pcg32::new(1234);
    let widths = engine.manifest().widths.clone();
    let mut receivers = Vec::new();
    let t0 = std::time::Instant::now();
    let mut submitted = 0usize;
    while submitted < n_requests {
        let ds = &datasets[rng.usize_below(datasets.len())];
        let n = store.dataset(ds)?.n;
        let key = RouteKey {
            model: models[rng.usize_below(models.len())].clone(),
            dataset: ds.clone(),
            width: Some(widths[rng.usize_below(widths.len())]),
            strategy: [Strategy::Afs, Strategy::Sfs, Strategy::Aes][rng.usize_below(3)],
            precision: if rng.f32() < 0.5 { Precision::U8Device } else { Precision::F32 },
        };
        let nodes: Vec<usize> = (0..8).map(|_| rng.usize_below(n)).collect();
        match coord.submit(key, nodes) {
            Ok((_, rx)) => {
                receivers.push(rx);
                submitted += 1;
            }
            Err(aes_spmm::coordinator::SubmitError::Busy) => {
                std::thread::sleep(std::time::Duration::from_micros(200));
            }
            Err(e) => bail!("submit failed: {e}"),
        }
    }
    let mut ok = 0usize;
    let mut reported = 0usize;
    for rx in receivers {
        let resp = rx.recv()?;
        if resp.error.is_none() {
            ok += 1;
        } else if reported < 3 {
            eprintln!("request {} failed: {:?}", resp.id, resp.error);
            reported += 1;
        }
    }
    let elapsed = t0.elapsed();
    let snap = coord.metrics().snapshot();
    println!("served {ok}/{n_requests} requests in {elapsed:?}");
    println!(
        "throughput: {:.1} req/s | batches: {} (amortization {:.1} req/exec)",
        ok as f64 / elapsed.as_secs_f64(),
        snap.batches,
        coord.metrics().amortization()
    );
    println!(
        "latency p50 {:?} p99 {:?} | queue wait p50 {:?} | exec p50 {:?} | load p50 {:?}",
        snap.latency_p50, snap.latency_p99, snap.queue_wait_p50, snap.exec_p50, snap.load_p50
    );
    println!(
        "plan cache: {} warm hits / {} cold builds ({} routes resident)",
        snap.plan_hits,
        snap.plan_misses,
        coord.plan_cache_len()
    );
    let pstats = coord.prefetch_stats();
    println!(
        "prefetch: {} staged / {} completed / {} coalesced / {} errors",
        pstats.scheduled, pstats.completed, pstats.coalesced, pstats.errors
    );
    let sstats = coord.shard_stats();
    println!(
        "shards: {} batches sharded | units: {} resident / {} warm / {} built / {} evicted",
        snap.sharded_batches, sstats.resident, sstats.hits, sstats.misses, sstats.evictions
    );
    println!("\nfeature staging per dataset (monotonic totals):");
    for ds in &datasets {
        let f = store.feature_store(ds)?;
        let t = f.totals();
        println!(
            "  {ds}: {} loads, {} bytes staged via {}, {:?} staging time",
            t.loads,
            t.bytes_read,
            f.source().name(),
            t.stage_time
        );
    }
    println!("\nper-route executions:");
    for (route, count) in &snap.per_route {
        println!("  {route}: {count}");
    }
    coord.shutdown();
    Ok(())
}

/// `repro serve --listen ADDR` — the TCP wire front-end: the
/// coordinator behind connection threads speaking the length-prefixed
/// protocol, with admission control and the ops request surface
/// (docs/serving.md). `--eval-data DIR` generates the seeded
/// conformance datasets and serves them on the host backend, so CI and
/// loadgen need no AOT artifacts.
fn cmd_serve_listen(artifacts: &str, args: &Args) -> Result<()> {
    use aes_spmm::coordinator::{NetConfig, WireServer};
    use aes_spmm::runtime::Backend;

    maybe_install_cost_model(args);
    let listen = args.get("listen").context("--listen needs HOST:PORT")?.to_string();
    if listen == "true" {
        bail!("--listen needs HOST:PORT (e.g. 127.0.0.1:0 for an ephemeral port)");
    }
    let sharding = if args.has("shards") || args.has("shard-budget") {
        Some(aes_spmm::graph::ShardSpec {
            shards: args
                .get("shards")
                .map(|s| s.parse().context("--shards must be an integer"))
                .transpose()?,
            budget_bytes: args.usize_or("shard-budget", 32)? << 20,
        })
    } else {
        None
    };
    let cfg = CoordinatorConfig {
        workers: args.usize_or("workers", 2)?,
        queue_depth: args.usize_or("queue", 1024)?,
        batcher: aes_spmm::coordinator::BatcherConfig {
            max_batch: args.usize_or("batch", 32)?,
            max_delay: std::time::Duration::from_millis(2),
        },
        prefetch_workers: args.usize_or("prefetch", 1)?,
        sharding,
        ..CoordinatorConfig::default()
    };

    let (store, backend) = if let Some(dir) = args.get("eval-data") {
        // Self-contained serving over the seeded conformance datasets:
        // the host substrate interprets any IR model, so the default
        // roster is the whole served zoo (docs/models.md).
        let models = models_flag(args, aes_spmm::runtime::SERVED_MODELS)?;
        let dir = std::path::PathBuf::from(dir);
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating {}", dir.display()))?;
        let names = aes_spmm::eval::write_eval_datasets(&dir)?;
        let store = ModelStore::load(&dir, &names, &models)?;
        println!(
            "eval data: {} dataset(s), models {} under {}",
            names.len(),
            models.join(","),
            dir.display()
        );
        (Arc::new(store), Backend::Host)
    } else if args.has("host") {
        let models = models_flag(args, &["gcn", "sage"])?;
        let engine = Engine::new(artifacts)?;
        let datasets = engine.manifest().dataset_names();
        let store = ModelStore::load(artifacts, &datasets, &models)?;
        (Arc::new(store), Backend::Host)
    } else {
        let models = models_flag(args, &["gcn", "sage"])?;
        let engine = Arc::new(Engine::new(artifacts)?);
        let datasets = engine.manifest().dataset_names();
        let store = ModelStore::load(artifacts, &datasets, &models)?;
        (Arc::new(store), Backend::Pjrt(engine))
    };

    let coord = Arc::new(Coordinator::start_with(backend, store.clone(), cfg));
    let net = NetConfig {
        high_water: args.usize_or("high-water", 256)?,
        ..NetConfig::default()
    };
    let server = WireServer::bind(coord, store, &listen, net)?;
    let addr = server.local_addr();
    println!("listening on {addr}");
    if let Some(path) = args.get("port-file") {
        // Written after the bind succeeds: pollers (ci.sh) read the
        // resolved ephemeral port from here.
        std::fs::write(path, addr.to_string())
            .with_context(|| format!("writing --port-file {path}"))?;
    }
    let max_seconds = args.usize_or("max-seconds", 0)?;
    let t0 = std::time::Instant::now();
    loop {
        std::thread::sleep(std::time::Duration::from_millis(200));
        if max_seconds > 0 && t0.elapsed().as_secs() >= max_seconds as u64 {
            println!("--max-seconds {max_seconds} reached; shutting down");
            break;
        }
    }
    server.shutdown();
    Ok(())
}

/// `repro shard-server` — a shard worker process: `serve --listen` with
/// row-sharding on by default, so `status` advertises multiple shard
/// row ranges for a router to place (docs/serving.md).
fn cmd_shard_server(artifacts: &str, args: &mut Args) -> Result<()> {
    if !args.has("listen") {
        bail!("shard-server requires --listen HOST:PORT");
    }
    if !args.has("shards") && !args.has("shard-budget") {
        args.flags.insert("shards".to_string(), "3".to_string());
    }
    cmd_serve_listen(artifacts, args)
}

/// `repro router` — the scatter/gather front of a shard-server fleet:
/// clients speak the ordinary wire protocol to it; it serves reads by
/// row-concatenating shard slices from the owning workers and writes by
/// broadcasting the epoch-tagged replication log (docs/serving.md).
fn cmd_router(args: &Args) -> Result<()> {
    use aes_spmm::coordinator::{RouterConfig, ShardRouter};

    let listen = args.get("listen").context("--listen needs HOST:PORT")?.to_string();
    if listen == "true" {
        bail!("--listen needs HOST:PORT (e.g. 127.0.0.1:0 for an ephemeral port)");
    }
    let workers_arg = args.get("workers").context("--workers HOST:PORT,... required")?;
    let worker_addrs: Vec<String> = workers_arg
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    if worker_addrs.is_empty() {
        bail!("--workers needs at least one HOST:PORT");
    }
    let cfg = RouterConfig {
        high_water: args.usize_or("high-water", 256)?,
        ..RouterConfig::default()
    };
    let router = ShardRouter::bind(&worker_addrs, &listen, cfg)?;
    let addr = router.local_addr();
    println!("router listening on {addr} over {} worker(s)", worker_addrs.len());
    if let Some(path) = args.get("port-file") {
        std::fs::write(path, addr.to_string())
            .with_context(|| format!("writing --port-file {path}"))?;
    }
    let max_seconds = args.usize_or("max-seconds", 0)?;
    let t0 = std::time::Instant::now();
    loop {
        std::thread::sleep(std::time::Duration::from_millis(200));
        if max_seconds > 0 && t0.elapsed().as_secs() >= max_seconds as u64 {
            println!("--max-seconds {max_seconds} reached; shutting down");
            break;
        }
    }
    router.shutdown();
    Ok(())
}

/// `repro loadgen` — offer scenario traffic to a live wire server and
/// report client-observed quantiles (docs/serving.md).
fn cmd_loadgen(args: &Args) -> Result<()> {
    use aes_spmm::loadgen::{merge_bench_json, run_loadgen, Scenario};

    let addr = args.get("addr").context("--addr HOST:PORT required")?;
    let mut scenario = match args.get("scenario") {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading scenario {path}"))?;
            Scenario::from_json(&text).with_context(|| format!("parsing scenario {path}"))?
        }
        None => Scenario::default(),
    };
    if args.has("quick") {
        scenario.quick();
    }
    if let Some(c) = args.get("connections") {
        scenario.connections = c.parse().context("--connections must be an integer")?;
    }
    let prefix = args.get("prefix").filter(|p| *p != "true").map(str::to_string);
    let report = run_loadgen(addr, &scenario)?;
    report.print();
    if args.has("json") {
        // Bare `--json` lands as the value "true": use the default path.
        let path = match args.get("json") {
            Some("true") | None => "BENCH_serving.json".to_string(),
            Some(p) => p.to_string(),
        };
        let fresh = report.to_json_prefixed(prefix.as_deref());
        let doc = if args.has("append") {
            match std::fs::read_to_string(&path) {
                // Merge into the existing trajectory file (how the
                // sharded-router pass lands next to the single-server
                // one in CI) — a missing file degrades to a plain write.
                Ok(existing) => merge_bench_json(&existing, &fresh)
                    .with_context(|| format!("appending workloads to {path}"))?,
                Err(_) => fresh,
            }
        } else {
            fresh
        };
        std::fs::write(&path, doc.to_string())
            .with_context(|| format!("writing {path}"))?;
        println!("wrote {path}");
    }
    Ok(())
}

/// Apply a live edge delta through the serving coordinator (host
/// backend): warm a route, apply, report the invalidation scope, and
/// verify the post-delta forward bitwise against a cold coordinator
/// built directly on the mutated graph (the `docs/mutation.md`
/// guarantee, checked on the operator's real data).
fn cmd_mutate(artifacts: &str, args: &Args) -> Result<()> {
    use aes_spmm::graph::GraphDelta;
    use aes_spmm::runtime::Backend;

    maybe_install_cost_model(args);
    let dataset = args.get("dataset").context("--dataset required")?.to_string();
    let edges = args.get("edges").context("--edges FILE required")?;
    let delta = GraphDelta::from_file(edges)?;
    let width = args.get("width").map(|w| w.parse::<usize>()).transpose()?;
    let strategy = Strategy::from_name(&args.get_or("strategy", "aes"))
        .context("--strategy must be afs|sfs|aes")?;
    let sharding = Some(aes_spmm::graph::ShardSpec {
        shards: args
            .get("shards")
            .map(|s| s.parse().context("--shards must be an integer"))
            .transpose()?,
        budget_bytes: args.usize_or("shard-budget", 32)? << 20,
    });

    let names = vec![dataset.clone()];
    let models = vec!["gcn".to_string()];
    let cfg = CoordinatorConfig { sharding, ..CoordinatorConfig::default() };
    let store = Arc::new(ModelStore::load(artifacts, &names, &models)?);
    let coord = Coordinator::start_with(Backend::Host, store.clone(), cfg.clone());
    let key = RouteKey {
        model: "gcn".to_string(),
        dataset: dataset.clone(),
        width,
        strategy,
        precision: Precision::default(),
    };

    // Warm the route, then mutate.
    let t0 = std::time::Instant::now();
    coord.route_logits(&key)?;
    let warm_time = t0.elapsed();
    let before = coord.shard_stats();
    let t1 = std::time::Instant::now();
    let outcome = coord.apply_delta(&dataset, &delta)?;
    let apply_time = t1.elapsed();
    coord.wait_prefetch_idle();
    let t2 = std::time::Instant::now();
    let logits = coord.route_logits(&key)?;
    let reserve_time = t2.elapsed();
    let after = coord.shard_stats();

    let r = &outcome.report;
    println!(
        "delta: {} op(s) → {} inserted / {} deleted / {} reweighted / {} no-op",
        delta.len(),
        r.inserted,
        r.deleted,
        r.reweighted,
        r.noops
    );
    println!(
        "graph: epoch {} | nnz {} → {} | {} row(s) touched",
        outcome.epoch,
        r.nnz_before,
        r.nnz_after,
        r.touched_rows.len()
    );
    println!(
        "invalidation: {} shard unit(s) re-sampled, {} retained warm{} | {} plan(s) dropped, \
         {} re-staged",
        outcome.shards_resampled,
        outcome.shards_retained,
        if outcome.repartitioned { " (layout re-cut: working-set drift)" } else { "" },
        outcome.plans_invalidated,
        outcome.routes_restaged
    );
    println!(
        "unit cache: {} resident | +{} hits / +{} misses since warm-up",
        after.resident,
        after.hits - before.hits,
        after.misses - before.misses
    );
    println!(
        "timing: warm-up {warm_time:?} | apply {apply_time:?} | post-delta serve {reserve_time:?}"
    );

    // The mutate-then-serve guarantee, on the operator's data: a cold
    // coordinator on the already-mutated graph must agree bitwise.
    let cold_store = Arc::new(ModelStore::load(artifacts, &names, &models)?);
    let cold = Coordinator::start_with(Backend::Host, cold_store, cfg);
    cold.apply_delta(&dataset, &delta)?;
    let want = cold.route_logits(&key)?;
    let (a, b) = (logits.as_f32()?, want.as_f32()?);
    let differing =
        a.iter().zip(b.iter()).filter(|(x, y)| x.to_bits() != y.to_bits()).count();
    if differing == 0 {
        println!("verify: post-delta forward is bitwise-equal to a cold rebuild");
    } else {
        cold.shutdown();
        coord.shutdown();
        bail!("post-delta forward differs from a cold rebuild in {differing} logit(s)");
    }
    cold.shutdown();
    coord.shutdown();
    Ok(())
}

fn cmd_experiment(artifacts: &str, args: &Args) -> Result<()> {
    let id = args
        .positional
        .first()
        .context("experiment id required (fig2/fig3/fig5/fig6/fig7/tab1/tab3/all)")?;
    let ctx = ExpContext::new(artifacts, args.has("quick"))?;
    let tables = experiments::run(&ctx, id)?;
    println!("\nwrote {} report(s) under {}", tables.len(), ctx.out_dir.display());
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    maybe_install_cost_model(args);
    let dir = args.get_or("dir", "target/acc-eval");
    let quick = args.has("quick");
    let report = aes_spmm::eval::run_eval(std::path::Path::new(&dir), quick)?;
    report.table().print();
    let failed_checks = report.checks.iter().filter(|c| !c.pass).count();
    println!(
        "checks: {}/{} passed ({} grid configs over {} datasets)",
        report.checks.len() - failed_checks,
        report.checks.len(),
        report.configs.len(),
        report.datasets.len()
    );
    if args.has("json") {
        // Bare `--json` lands as the value "true": use the default path.
        let path = match args.get("json") {
            Some("true") | None => "ACC_eval.json".to_string(),
            Some(p) => p.to_string(),
        };
        std::fs::write(&path, report.to_json().to_string())
            .with_context(|| format!("writing {path}"))?;
        println!("wrote {path}");
    }
    if !report.pass() {
        bail!("accuracy budgets violated:\n  {}", report.failures().join("\n  "));
    }
    println!("accuracy conformance: every configuration within budget");
    Ok(())
}

fn cmd_gen_data(args: &Args) -> Result<()> {
    let n = args.usize_or("nodes", 4096)?;
    let avg_deg: f64 = args.get_or("avg-deg", "16").parse()?;
    let gamma: f64 = args.get_or("gamma", "2.0").parse()?;
    let seed: u64 = args.get_or("seed", "0").parse()?;
    let mut rng = Pcg32::new(seed);
    let g = gen::with_self_loops(&gen::chung_lu(n, avg_deg, gamma, &mut rng));
    let stats = DegreeStats::of(&g);
    println!("generated: n={} nnz={} sparsity={:.6}%", g.n_rows, g.nnz(), g.sparsity_pct());
    println!(
        "degrees: min {} max {} mean {:.1} median {} p90 {} p99 {}",
        stats.min, stats.max, stats.mean, stats.median, stats.p90, stats.p99
    );
    for (w, frac) in &stats.frac_within {
        println!("  deg <= {w}: {:.1}%", frac * 100.0);
    }
    for strat in Strategy::ALL {
        for w in [16, 64, 256] {
            println!(
                "sampling rate {} W={w}: {:.3}",
                strat.name(),
                aes_spmm::sampling::sampling_rate(&g, w, strat)
            );
        }
    }
    Ok(())
}
