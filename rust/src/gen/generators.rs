//! Graph generators: Erdős–Rényi, Chung-Lu power-law, degree-corrected
//! SBM (the dataset-analog model), and RMAT (skew stress test).
//!
//! All generators return validated, deduplicated, symmetric CSR graphs
//! with unit values; callers re-weight (e.g. [`crate::graph::Csr::gcn_normalized`]).

use std::collections::HashSet;

use crate::graph::{coo_to_csr, Csr};
use crate::rng::Pcg32;

/// Deduplicate + symmetrize COO pairs and build a unit-valued CSR.
fn build_symmetric(n: usize, pairs: impl IntoIterator<Item = (u32, u32)>) -> Csr {
    let mut seen: HashSet<u64> = HashSet::new();
    let mut triples: Vec<(i32, i32, f32)> = Vec::new();
    for (u, v) in pairs {
        if u == v {
            continue;
        }
        let key = ((u.min(v) as u64) << 32) | u.max(v) as u64;
        if seen.insert(key) {
            triples.push((u as i32, v as i32, 1.0));
            triples.push((v as i32, u as i32, 1.0));
        }
    }
    coo_to_csr(n, n, triples).expect("generator produced invalid CSR")
}

/// G(n, m): `m` uniform random undirected edges (deduplicated).
pub fn erdos_renyi(n: usize, m: usize, rng: &mut Pcg32) -> Csr {
    let pairs = (0..m).map(|_| (rng.below(n as u32), rng.below(n as u32)));
    build_symmetric(n, pairs.collect::<Vec<_>>())
}

/// Chung-Lu with power-law expected degrees: weight_i ∝ (i+i0)^(-1/(γ-1)),
/// shuffled, scaled to hit `avg_deg`. Endpoints drawn weight-biased via a
/// cumulative table + binary search.
pub fn chung_lu(n: usize, avg_deg: f64, gamma: f64, rng: &mut Pcg32) -> Csr {
    let weights = power_law_weights(n, gamma, rng);
    let cum = cumulative(&weights);
    let m = (avg_deg * n as f64 / 2.0) as usize;
    let pairs: Vec<(u32, u32)> = (0..m)
        .map(|_| (draw(&cum, rng) as u32, draw(&cum, rng) as u32))
        .collect();
    build_symmetric(n, pairs)
}

/// Configuration for the degree-corrected SBM used by the dataset analogs.
#[derive(Clone, Debug)]
pub struct DcSbmConfig {
    pub n: usize,
    pub avg_deg: f64,
    /// Power-law exponent for expected degrees; 0.0 = mild lognormal-free
    /// uniform weights.
    pub gamma: f64,
    pub communities: usize,
    /// Probability an edge's second endpoint stays within the community.
    pub homophily: f64,
}

/// Degree-corrected SBM. Returns (graph, community labels).
pub fn dc_sbm(cfg: &DcSbmConfig, rng: &mut Pcg32) -> (Csr, Vec<i32>) {
    let n = cfg.n;
    let comm: Vec<i32> = (0..n).map(|_| rng.below(cfg.communities as u32) as i32).collect();
    let weights = if cfg.gamma > 0.0 {
        power_law_weights(n, cfg.gamma, rng)
    } else {
        vec![1.0; n]
    };
    let cum = cumulative(&weights);

    // Per-community cumulative tables for the homophilous draws.
    let mut members: Vec<Vec<usize>> = vec![Vec::new(); cfg.communities];
    for (i, &c) in comm.iter().enumerate() {
        members[c as usize].push(i);
    }
    let member_cums: Vec<(Vec<f64>, &Vec<usize>)> = members
        .iter()
        .map(|ms| {
            let w: Vec<f64> = ms.iter().map(|&i| weights[i]).collect();
            (cumulative(&w), ms)
        })
        .collect();

    let m = (cfg.avg_deg * n as f64 / 2.0) as usize;
    let mut pairs = Vec::with_capacity(m);
    for _ in 0..m {
        let u = draw(&cum, rng);
        let v = if (rng.f64() < cfg.homophily) && !members[comm[u] as usize].is_empty() {
            let (mcum, ms) = &member_cums[comm[u] as usize];
            ms[draw(mcum, rng)]
        } else {
            draw(&cum, rng)
        };
        pairs.push((u as u32, v as u32));
    }
    (build_symmetric(n, pairs), comm)
}

/// RMAT (Chakrabarti et al.): recursive quadrant splits, heavy skew.
pub fn rmat(scale: u32, avg_deg: f64, rng: &mut Pcg32) -> Csr {
    let n = 1usize << scale;
    let m = (avg_deg * n as f64 / 2.0) as usize;
    let (a, b, c) = (0.57, 0.19, 0.19);
    let pairs: Vec<(u32, u32)> = (0..m)
        .map(|_| {
            let (mut u, mut v) = (0u32, 0u32);
            for _ in 0..scale {
                let r = rng.f64();
                let (du, dv) = if r < a {
                    (0, 0)
                } else if r < a + b {
                    (0, 1)
                } else if r < a + b + c {
                    (1, 0)
                } else {
                    (1, 1)
                };
                u = (u << 1) | du;
                v = (v << 1) | dv;
            }
            (u, v)
        })
        .collect();
    build_symmetric(n, pairs)
}

/// Add self loops (GCN's A + I) to every node, keeping CSR sorted.
pub fn with_self_loops(csr: &Csr) -> Csr {
    let mut triples: Vec<(i32, i32, f32)> = Vec::with_capacity(csr.nnz() + csr.n_rows);
    for i in 0..csr.n_rows {
        let mut has_self = false;
        for e in csr.row_range(i) {
            triples.push((i as i32, csr.col_ind[e], csr.val[e]));
            has_self |= csr.col_ind[e] as usize == i;
        }
        if !has_self {
            triples.push((i as i32, i as i32, 1.0));
        }
    }
    coo_to_csr(csr.n_rows, csr.n_cols, triples).expect("self-loop augmentation broke CSR")
}

fn power_law_weights(n: usize, gamma: f64, rng: &mut Pcg32) -> Vec<f64> {
    let mut w: Vec<f64> = (1..=n)
        .map(|i| ((i + 10) as f64).powf(-1.0 / (gamma - 1.0)))
        .collect();
    rng.shuffle(&mut w);
    w
}

fn cumulative(weights: &[f64]) -> Vec<f64> {
    let mut cum = Vec::with_capacity(weights.len());
    let mut acc = 0.0;
    for &w in weights {
        acc += w;
        cum.push(acc);
    }
    cum
}

/// Weight-biased index draw via binary search on the cumulative table.
fn draw(cum: &[f64], rng: &mut Pcg32) -> usize {
    let total = *cum.last().expect("empty weight table");
    let x = rng.f64() * total;
    cum.partition_point(|&c| c <= x).min(cum.len() - 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn erdos_renyi_basic_shape() {
        let mut rng = Pcg32::new(1);
        let g = erdos_renyi(200, 800, &mut rng);
        g.validate().unwrap();
        assert_eq!(g.n_rows, 200);
        // symmetric + dedup: nnz is even and <= 2*m
        assert_eq!(g.nnz() % 2, 0);
        assert!(g.nnz() <= 1600);
        assert!(g.nnz() > 1000, "dedup shouldn't eat most edges at this density");
    }

    #[test]
    fn generators_are_deterministic() {
        let g1 = chung_lu(300, 10.0, 2.0, &mut Pcg32::new(7));
        let g2 = chung_lu(300, 10.0, 2.0, &mut Pcg32::new(7));
        assert_eq!(g1, g2);
    }

    #[test]
    fn chung_lu_hits_target_degree_and_skew() {
        let mut rng = Pcg32::new(2);
        let g = chung_lu(2000, 30.0, 1.8, &mut rng);
        g.validate().unwrap();
        // Dedup collapses repeated hub pairs, so realized degree sits below
        // the 30 requested; it must still be in the right ballpark.
        let avg = g.avg_degree();
        assert!((15.0..40.0).contains(&avg), "avg degree {avg} too far from 30");
        // Power law: max degree far above mean.
        assert!(g.max_degree() as f64 > 4.0 * avg, "expected heavy tail");
    }

    #[test]
    fn symmetry_holds() {
        let mut rng = Pcg32::new(3);
        let g = chung_lu(400, 8.0, 2.0, &mut rng);
        let t = g.transpose();
        assert_eq!(g, t, "undirected graph should equal its transpose");
    }

    #[test]
    fn dc_sbm_homophily_measurable() {
        let mut rng = Pcg32::new(4);
        let cfg =
            DcSbmConfig { n: 1000, avg_deg: 20.0, gamma: 0.0, communities: 5, homophily: 0.9 };
        let (g, comm) = dc_sbm(&cfg, &mut rng);
        g.validate().unwrap();
        let mut intra = 0usize;
        for i in 0..g.n_rows {
            for e in g.row_range(i) {
                if comm[i] == comm[g.col_ind[e] as usize] {
                    intra += 1;
                }
            }
        }
        let frac = intra as f64 / g.nnz() as f64;
        // Homophilous second endpoint + random first: expect well above 1/5.
        assert!(frac > 0.6, "intra-community fraction {frac} too low");
    }

    #[test]
    fn rmat_is_skewed() {
        let mut rng = Pcg32::new(5);
        let g = rmat(10, 16.0, &mut rng);
        g.validate().unwrap();
        assert!(g.max_degree() > 8 * g.avg_degree() as usize);
    }

    #[test]
    fn self_loops_present_and_idempotent() {
        let mut rng = Pcg32::new(6);
        let g = with_self_loops(&erdos_renyi(100, 300, &mut rng));
        g.validate().unwrap();
        for i in 0..g.n_rows {
            assert!(
                g.row_range(i).any(|e| g.col_ind[e] as usize == i),
                "node {i} lacks self loop"
            );
        }
        let g2 = with_self_loops(&g);
        assert_eq!(g.nnz(), g2.nnz(), "idempotent");
    }
}
