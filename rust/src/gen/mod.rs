//! Synthetic graph generators — workload substrate for benches, property
//! tests, and standalone experiments (dataset artifacts themselves are
//! generated once at build time by `python/compile/datagen.py`; these
//! rust generators produce *structurally equivalent* graphs for the parts
//! of the evaluation that live purely in rust, e.g. the Fig. 7 CPU kernel
//! sweeps and the coordinator load tests).

mod generators;

pub use generators::{chung_lu, dc_sbm, erdos_renyi, rmat, with_self_loops, DcSbmConfig};
