#!/usr/bin/env bash
# CI gate: format, lint, build, test — and optionally refresh the SpMM
# perf baseline (./ci.sh --bench).
set -euo pipefail
cd "$(dirname "$0")"

echo "== cargo fmt --check =="
cargo fmt --all -- --check

echo "== cargo clippy (deny warnings) =="
cargo clippy --workspace --all-targets -- -D warnings

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

if [[ "${1:-}" == "--bench" ]]; then
    echo "== perf baseline: BENCH_spmm.json =="
    cargo bench --bench spmm_kernels -- --json BENCH_spmm.json
fi

echo "CI OK"
