#!/usr/bin/env bash
# CI gate: format, lint, build, test — and optionally refresh the perf
# baselines (./ci.sh --bench) and diff them against the committed ones.
#
# The workspace has no registry dependencies (everything is vendored
# under /vendor as path deps), so cargo runs fully offline; CI exports
# CARGO_NET_OFFLINE=true and network-restricted runners pass.
#
# Modes:
#   ./ci.sh               full gate (fmt, clippy, build, test, docs)
#   ./ci.sh --bench       full gate, then benches + bench_diff regression gate
#   ./ci.sh --bench-only  benches + bench_diff only (CI's bench job, which
#                         already ran the gate via its `needs:` dependency)
#   ./ci.sh --eval-only   accuracy conformance (repro eval -> ACC_eval.json)
#                         + acc_diff regression gate (CI's eval job)
#   ./ci.sh --tune-only   autotuner gate: repro tune --quick -> COST_spmm.json,
#                         schema validation, and a bench pass asserting the
#                         tuned-dispatch case landed (CI's tune job)
#   ./ci.sh --serve-only  serving gate: boot `repro serve --listen` on a
#                         loopback ephemeral port, drive it with
#                         `repro loadgen --quick` -> BENCH_serving.json; then
#                         boot the 3-process sharded topology (2x shard-server
#                         + router) and fold a second loadgen pass in under a
#                         "sharded-router" prefix; diff the merged report
#                         against the committed baseline (CI's serving job;
#                         bootstrap-pass while the baseline is unseeded)
#
# Env knobs:
#   SKIP_LINT=1   skip the fmt + clippy steps (e.g. a toolchain without
#                 the components; the error below tells you how to add them)
#   AES_SPMM_FORCE_SCALAR=1
#                 pin every runtime SIMD dispatch site to the scalar arm
#                 (docs/simd.md); the whole gate must pass bit-identically
#                 in this configuration — CI's `scalar` job runs it
set -euo pipefail
cd "$(dirname "$0")"

export CARGO_NET_OFFLINE="${CARGO_NET_OFFLINE:-true}"

die() {
    echo "ci.sh: error: $1" >&2
    shift
    for line in "$@"; do echo "  $line" >&2; done
    exit 1
}

command -v cargo >/dev/null 2>&1 || die \
    "cargo is not on PATH." \
    "fix: install rust via https://rustup.rs (or your distro's rustup package)," \
    "then re-run ./ci.sh"

run_benches() {
    # Bench binaries run with cwd = the aes-spmm package dir (rust/), so
    # pass absolute output paths to land the JSONs at the repo root where
    # bench_diff, the committed baselines, and the CI artifact upload
    # expect them.
    echo "== perf baseline: BENCH_spmm.json =="
    cargo bench --bench spmm_kernels -- --json "$PWD/BENCH_spmm.json"
    echo "== perf baseline: BENCH_loading.json =="
    cargo bench --bench loading -- --json "$PWD/BENCH_loading.json"
    echo "== bench regression gate (>15% median slowdown fails) =="
    cargo run --release -p aes-spmm --bin bench_diff -- \
        BENCH_spmm.json benchmarks/baseline/BENCH_spmm.json --threshold 0.15
    cargo run --release -p aes-spmm --bin bench_diff -- \
        BENCH_loading.json benchmarks/baseline/BENCH_loading.json --threshold 0.15
}

run_eval_gate() {
    # The conformance grid needs no artifacts: seeded datasets are
    # generated under target/, served on the host backend, and scored
    # against the exact oracle. `repro eval` exits nonzero on any
    # budget violation; acc_diff additionally fails on top-1 agreement
    # drops vs the committed baseline (bootstrap-pass while
    # benchmarks/baseline/ACC_eval.json is unseeded).
    echo "== accuracy conformance: ACC_eval.json =="
    cargo run --release -p aes-spmm --bin repro -- \
        eval --json "$PWD/ACC_eval.json" --dir "$PWD/target/acc-eval"
    echo "== accuracy regression gate (budget violation or agreement drop fails) =="
    cargo run --release -p aes-spmm --bin acc_diff -- \
        ACC_eval.json benchmarks/baseline/ACC_eval.json
}

run_tune_gate() {
    # The autotuner must (a) emit a schema-valid profile that its own
    # validator round-trips, and (b) keep the tuned-dispatch bench case
    # alive: spmm_kernels builds an argmin cost model over the forced
    # single-format cases and benches the dispatcher through it, so the
    # case's presence in the fresh JSON is the bench-level proof the
    # measured model tracks the best single-format configuration.
    echo "== autotune: COST_spmm.json (quick) =="
    cargo run --release -p aes-spmm --bin repro -- \
        tune --quick --out "$PWD/COST_spmm.json"
    echo "== cost-model schema validation =="
    cargo run --release -p aes-spmm --bin repro -- \
        tune --validate "$PWD/COST_spmm.json"
    echo "== tuned-vs-forced bench case =="
    cargo bench --bench spmm_kernels -- --json "$PWD/BENCH_spmm.json"
    grep -q '"tuned dispatch (exact)' "$PWD/BENCH_spmm.json" || die \
        "BENCH_spmm.json has no 'tuned dispatch (exact)' case." \
        "the tuned-dispatch bench in rust/benches/spmm_kernels.rs was removed or renamed;" \
        "see docs/dispatch.md (CI section)"
    grep -q '"forced bcsr' "$PWD/BENCH_spmm.json" || die \
        "BENCH_spmm.json has no forced single-format cases to compare against." \
        "see docs/dispatch.md (CI section)"
}

run_serving_gate() {
    # End-to-end over real TCP: a live server on an ephemeral loopback
    # port (eval datasets, host backend — no artifacts), the closed-loop
    # generator against it, then the bench_diff gate over the latency
    # quantiles + throughput it measured. The threshold is deliberately
    # loose (50%, 500µs noise floor): shared-runner serving latency is
    # far noisier than the in-process microbenches, and the throughput
    # case diffs direction-aware (a drop regresses, a gain passes).
    echo "== serving gate: BENCH_serving.json =="
    cargo build --release -p aes-spmm --bin repro --bin bench_diff
    local addr_file="$PWD/target/serving-addr.txt"
    rm -f "$addr_file"
    ./target/release/repro serve --listen 127.0.0.1:0 \
        --eval-data "$PWD/target/serve-eval" \
        --port-file "$addr_file" --max-seconds 600 &
    local server_pid=$!
    # The addr file appears once the listener is bound.
    local waited=0
    while [[ ! -s "$addr_file" ]]; do
        kill -0 "$server_pid" 2>/dev/null || die \
            "the serving process exited before binding its listener." \
            "re-run './target/release/repro serve --listen 127.0.0.1:0 --eval-data target/serve-eval' by hand to see why"
        sleep 0.2
        waited=$((waited + 1))
        [[ "$waited" -lt 150 ]] || { kill "$server_pid" 2>/dev/null || true; die \
            "the serving process never wrote $addr_file within 30s."; }
    done
    local addr
    addr="$(cat "$addr_file")"
    echo "== loadgen --quick against $addr =="
    local loadgen_rc=0
    ./target/release/repro loadgen --addr "$addr" --quick \
        --json "$PWD/BENCH_serving.json" || loadgen_rc=$?
    kill "$server_pid" 2>/dev/null || true
    wait "$server_pid" 2>/dev/null || true
    [[ "$loadgen_rc" -eq 0 ]] || die "repro loadgen failed (exit $loadgen_rc)"

    # Second pass: the 3-process sharded topology (two shard-server
    # workers + a router, all on ephemeral loopback ports). The loadgen
    # workloads fold into the same BENCH_serving.json under a
    # "sharded-router" prefix so the one baseline file gates both
    # topologies (docs/serving.md).
    echo "== sharded topology: 2x shard-server + router =="
    local w1_file="$PWD/target/serving-worker1.txt"
    local w2_file="$PWD/target/serving-worker2.txt"
    local router_file="$PWD/target/serving-router.txt"
    rm -f "$w1_file" "$w2_file" "$router_file"
    ./target/release/repro shard-server --listen 127.0.0.1:0 \
        --eval-data "$PWD/target/serve-eval-w1" \
        --port-file "$w1_file" --max-seconds 600 &
    local w1_pid=$!
    ./target/release/repro shard-server --listen 127.0.0.1:0 \
        --eval-data "$PWD/target/serve-eval-w2" \
        --port-file "$w2_file" --max-seconds 600 &
    local w2_pid=$!
    kill_fleet() {
        for pid in "$@"; do
            kill "$pid" 2>/dev/null || true
            wait "$pid" 2>/dev/null || true
        done
    }
    waited=0
    while [[ ! -s "$w1_file" || ! -s "$w2_file" ]]; do
        if ! kill -0 "$w1_pid" 2>/dev/null || ! kill -0 "$w2_pid" 2>/dev/null; then
            kill_fleet "$w1_pid" "$w2_pid"
            die "a shard-server process exited before binding its listener."
        fi
        sleep 0.2
        waited=$((waited + 1))
        [[ "$waited" -lt 150 ]] || { kill_fleet "$w1_pid" "$w2_pid"; die \
            "the shard-server processes never wrote their port files within 30s."; }
    done
    ./target/release/repro router --listen 127.0.0.1:0 \
        --workers "$(cat "$w1_file"),$(cat "$w2_file")" \
        --port-file "$router_file" --max-seconds 600 &
    local router_pid=$!
    waited=0
    while [[ ! -s "$router_file" ]]; do
        kill -0 "$router_pid" 2>/dev/null || { kill_fleet "$w1_pid" "$w2_pid"; die \
            "the router process exited before binding its listener."; }
        sleep 0.2
        waited=$((waited + 1))
        [[ "$waited" -lt 150 ]] || { kill_fleet "$router_pid" "$w1_pid" "$w2_pid"; die \
            "the router never wrote $router_file within 30s."; }
    done
    local router_addr
    router_addr="$(cat "$router_file")"
    echo "== loadgen --quick against router $router_addr =="
    loadgen_rc=0
    ./target/release/repro loadgen --addr "$router_addr" --quick \
        --prefix sharded-router --append \
        --json "$PWD/BENCH_serving.json" || loadgen_rc=$?
    kill_fleet "$router_pid" "$w1_pid" "$w2_pid"
    [[ "$loadgen_rc" -eq 0 ]] || die "repro loadgen (sharded-router) failed (exit $loadgen_rc)"
    echo "== serving regression gate (direction-aware; >50% drift fails) =="
    cargo run --release -p aes-spmm --bin bench_diff -- \
        BENCH_serving.json benchmarks/baseline/BENCH_serving.json \
        --threshold 0.50 --min-median-us 500
}

if [[ "${1:-}" == "--bench-only" ]]; then
    run_benches
    echo "CI OK (bench only)"
    exit 0
fi

if [[ "${1:-}" == "--eval-only" ]]; then
    run_eval_gate
    echo "CI OK (eval only)"
    exit 0
fi

if [[ "${1:-}" == "--tune-only" ]]; then
    run_tune_gate
    echo "CI OK (tune only)"
    exit 0
fi

if [[ "${1:-}" == "--serve-only" ]]; then
    run_serving_gate
    echo "CI OK (serve only)"
    exit 0
fi

if [[ "${SKIP_LINT:-0}" != "1" ]]; then
    # A bare `set -e` death inside `cargo fmt`/`cargo clippy` on machines
    # without the components is useless — probe first and explain.
    cargo fmt --version >/dev/null 2>&1 || die \
        "the rustfmt component is missing for $(rustc --version 2>/dev/null || echo 'this toolchain')." \
        "fix: rustup component add rustfmt" \
        "or:  SKIP_LINT=1 ./ci.sh   (build + test only)"
    cargo clippy --version >/dev/null 2>&1 || die \
        "the clippy component is missing for $(rustc --version 2>/dev/null || echo 'this toolchain')." \
        "fix: rustup component add clippy" \
        "or:  SKIP_LINT=1 ./ci.sh   (build + test only)"

    echo "== cargo fmt --check =="
    cargo fmt --all -- --check

    echo "== cargo clippy (deny warnings) =="
    cargo clippy --workspace --all-targets -- -D warnings
else
    echo "== SKIP_LINT=1: skipping fmt + clippy =="
fi

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

# Docs gate: rustdoc warnings (broken intra-doc links, bad code fences)
# are errors, and `exec` / `quant` carry #![warn(missing_docs)] so every
# public item in those modules must be documented.
echo "== cargo doc (deny warnings) =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps -p aes-spmm

if [[ "${1:-}" == "--bench" ]]; then
    run_benches
fi

echo "CI OK"
