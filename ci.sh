#!/usr/bin/env bash
# CI gate: format, lint, build, test — and optionally refresh the SpMM
# perf baseline (./ci.sh --bench).
set -euo pipefail
cd "$(dirname "$0")"

echo "== cargo fmt --check =="
cargo fmt --all -- --check

echo "== cargo clippy (deny warnings) =="
cargo clippy --workspace --all-targets -- -D warnings

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

# Docs gate: rustdoc warnings (broken intra-doc links, bad code fences)
# are errors, and `exec` / `quant` carry #![warn(missing_docs)] so every
# public item in those modules must be documented.
echo "== cargo doc (deny warnings) =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps -p aes-spmm

if [[ "${1:-}" == "--bench" ]]; then
    echo "== perf baseline: BENCH_spmm.json =="
    cargo bench --bench spmm_kernels -- --json BENCH_spmm.json
    echo "== perf baseline: BENCH_loading.json =="
    cargo bench --bench loading -- --json BENCH_loading.json
fi

echo "CI OK"
