"""L1 kernel correctness: Pallas kernels vs the pure-numpy oracle.

This is the core correctness signal of the repo: the sampling kernel must
match ref.py bit-for-bit on integer outputs (column indices, slot counts)
and to float tolerance on products, for every strategy, across randomized
shapes (hypothesis drives the sweep).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import ref
from compile.kernels.aes_spmm import aes_sample, aes_spmm, spmm_ell
from compile.kernels.dequant import dequant

STRATEGIES = [ref.AFS, ref.SFS, ref.AES]


def random_csr(rng, n, max_deg):
    deg = rng.integers(0, max_deg, n)
    row_ptr = np.zeros(n + 1, np.int32)
    row_ptr[1:] = np.cumsum(deg)
    e = int(row_ptr[-1])
    col = rng.integers(0, n, e).astype(np.int32)
    val = rng.standard_normal(e).astype(np.float32)
    return row_ptr, col, val


@pytest.mark.parametrize("strategy", STRATEGIES)
@pytest.mark.parametrize("width", [16, 32, 64])
def test_sample_matches_ref(strategy, width):
    rng = np.random.default_rng(width * 10 + strategy)
    row_ptr, col, val = random_csr(rng, 80, width * 6)
    ev_r, ec_r, sl_r = ref.sample_ell(row_ptr, col, val, width, strategy)
    s = jnp.array([strategy], jnp.int32)
    ev, ec, sl = aes_sample(jnp.array(row_ptr), jnp.array(col), jnp.array(val), s, width=width)
    np.testing.assert_array_equal(np.asarray(ec), ec_r)
    np.testing.assert_array_equal(np.asarray(sl), sl_r)
    np.testing.assert_allclose(np.asarray(ev), ev_r, rtol=1e-6)


@pytest.mark.parametrize("strategy", STRATEGIES)
@pytest.mark.parametrize("mean", [False, True])
def test_fused_matches_ref(strategy, mean):
    rng = np.random.default_rng(7 + strategy)
    n, width, f = 60, 16, 9
    row_ptr, col, val = random_csr(rng, n, 120)
    b = rng.standard_normal((n, f)).astype(np.float32)
    want = ref.aes_spmm(row_ptr, col, val, b, width, strategy, mean=mean)
    got = aes_spmm(
        jnp.array(row_ptr), jnp.array(col), jnp.array(val), jnp.array(b),
        jnp.array([strategy], jnp.int32), width=width, mean=mean,
    )
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-4)


def test_fused_equals_two_stage():
    """The fused kernel must equal sample + spmm_ell composition."""
    rng = np.random.default_rng(3)
    n, width, f = 50, 32, 8
    row_ptr, col, val = random_csr(rng, n, 200)
    b = rng.standard_normal((n, f)).astype(np.float32)
    s = jnp.array([ref.AES], jnp.int32)
    ev, ec, _ = aes_sample(jnp.array(row_ptr), jnp.array(col), jnp.array(val), s, width=width)
    two_stage = spmm_ell(ev, ec, jnp.array(b))
    fused = aes_spmm(
        jnp.array(row_ptr), jnp.array(col), jnp.array(val), jnp.array(b), s, width=width
    )
    np.testing.assert_allclose(np.asarray(fused), np.asarray(two_stage), rtol=1e-5, atol=1e-5)


def test_width_at_least_max_degree_is_exact():
    """With W >= max row_nnz, sampling keeps everything => exact SpMM."""
    rng = np.random.default_rng(11)
    n, f = 40, 5
    row_ptr, col, val = random_csr(rng, n, 20)
    width = int(np.diff(row_ptr).max())
    b = rng.standard_normal((n, f)).astype(np.float32)
    exact = ref.csr_spmm(row_ptr, col, val, b)
    for strategy in STRATEGIES:
        got = aes_spmm(
            jnp.array(row_ptr), jnp.array(col), jnp.array(val), jnp.array(b),
            jnp.array([strategy], jnp.int32), width=width,
        )
        np.testing.assert_allclose(np.asarray(got), exact, rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(4, 60),
    max_deg=st.integers(1, 300),
    width=st.sampled_from([16, 32, 64, 128]),
    strategy=st.sampled_from(STRATEGIES),
    seed=st.integers(0, 2**31 - 1),
)
def test_sample_property_sweep(n, max_deg, width, strategy, seed):
    """Hypothesis sweep: kernel == oracle for arbitrary CSR shapes."""
    rng = np.random.default_rng(seed)
    row_ptr, col, val = random_csr(rng, n, max_deg)
    ev_r, ec_r, sl_r = ref.sample_ell(row_ptr, col, val, width, strategy)
    s = jnp.array([strategy], jnp.int32)
    ev, ec, sl = aes_sample(jnp.array(row_ptr), jnp.array(col), jnp.array(val), s, width=width)
    np.testing.assert_array_equal(np.asarray(ec), ec_r)
    np.testing.assert_array_equal(np.asarray(sl), sl_r)
    np.testing.assert_allclose(np.asarray(ev), ev_r, rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(
    nnz=st.integers(0, 5000),
    width=st.sampled_from([16, 32, 64, 128, 256]),
    strategy=st.sampled_from(STRATEGIES),
)
def test_plan_invariants(nnz, width, strategy):
    """Eq. 3 + Table 1 invariants: offsets in range, slot layout correct."""
    offs = ref.sample_row(nnz, width, strategy)
    n_, cnt = ref.strategy_params(nnz, width, strategy)
    slots = min(n_ * cnt, width)
    assert (offs[:slots] >= 0).all()
    if nnz:
        assert (offs[:slots] < nnz).all()
    assert (offs[slots:] == -1).all()
    # Slot layout: slot k = sample (k % cnt), run offset (k // cnt).
    for k in range(slots):
        s, j = k % cnt, k // cnt
        assert offs[k] == ref.start_index(s, nnz, n_) + j


def test_strategy_table_boundaries():
    """Table 1 thresholds at exact boundaries."""
    w = 64
    assert ref.strategy_params(w, w, ref.AES) == (w, 1)
    assert ref.strategy_params(w + 1, w, ref.AES) == (w // 4, 4)
    assert ref.strategy_params(2 * w, w, ref.AES) == (w // 4, 4)
    assert ref.strategy_params(2 * w + 1, w, ref.AES) == (w // 8, 8)
    assert ref.strategy_params(36 * w, w, ref.AES) == (w // 8, 8)
    assert ref.strategy_params(36 * w + 1, w, ref.AES) == (w // 16, 16)
    assert ref.strategy_params(54 * w, w, ref.AES) == (w // 16, 16)
    assert ref.strategy_params(54 * w + 1, w, ref.AES) == (w // 32, 32)
    # Small-W clamps (N >= 1, cnt <= W).
    assert ref.strategy_params(16 * 55, 16, ref.AES) == (1, 16)


def test_dequant_kernel_matches_ref_and_bounds():
    rng = np.random.default_rng(5)
    x = (rng.standard_normal((30, 12)) * 4).astype(np.float32)
    q, lo, hi = ref.quantize(x)
    got = dequant(jnp.array(q), jnp.array([lo], jnp.float32), jnp.array([hi], jnp.float32))
    np.testing.assert_allclose(np.asarray(got), ref.dequantize(q, lo, hi), atol=1e-5)
    assert np.abs(np.asarray(got) - x).max() <= (hi - lo) / 255 + 1e-5


@settings(max_examples=15, deadline=None)
@given(
    lo=st.floats(-100, 99, allow_nan=False),
    span=st.floats(0.001, 200, allow_nan=False),
    seed=st.integers(0, 1000),
)
def test_quant_roundtrip_property(lo, span, seed):
    rng = np.random.default_rng(seed)
    x = (lo + span * rng.random((20, 7))).astype(np.float32)
    q, qlo, qhi = ref.quantize(x)
    back = ref.dequantize(q, qlo, qhi)
    step = (qhi - qlo) / 255
    assert np.abs(back - x).max() <= step + 1e-4 * max(abs(qlo), abs(qhi), 1.0)


def test_sampling_rate_monotone_and_exact_at_max_degree():
    rng = np.random.default_rng(9)
    row_ptr, _, _ = random_csr(rng, 100, 500)
    last = 0.0
    for w in [16, 32, 64, 128, 256, 512]:
        r = ref.sampling_rate(row_ptr, w, ref.AES)
        assert r >= last - 1e-12
        last = r
    wmax = int(np.diff(row_ptr).max())
    assert ref.sampling_rate(row_ptr, wmax, ref.AES) == 1.0
