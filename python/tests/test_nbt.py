"""NBT container round-trip + cross-language golden file.

The rust reader is tested against a golden file with the same layout in
rust/tests/; here we pin the python side and the byte-level format.
"""

import struct

import numpy as np
import pytest

from compile.nbt import MAGIC, read_nbt, write_nbt


def test_roundtrip(tmp_path):
    path = str(tmp_path / "t.nbt")
    tensors = {
        "a": np.arange(12, dtype=np.float32).reshape(3, 4),
        "b": np.array([-1, 5, 9], dtype=np.int32),
        "q": np.array([[0, 255], [7, 128]], dtype=np.uint8),
        "m": np.array([1, 2, 3], dtype=np.int64),
    }
    write_nbt(path, tensors)
    back = read_nbt(path)
    assert list(back) == list(tensors)
    for k in tensors:
        np.testing.assert_array_equal(back[k], tensors[k])
        assert back[k].dtype == tensors[k].dtype


def test_header_layout(tmp_path):
    """Byte-level layout must match the documented format (rust relies on it)."""
    path = str(tmp_path / "h.nbt")
    write_nbt(path, {"x": np.array([1.5], dtype=np.float32)})
    raw = open(path, "rb").read()
    assert raw[:4] == MAGIC
    (count,) = struct.unpack_from("<I", raw, 4)
    assert count == 1
    (nlen,) = struct.unpack_from("<H", raw, 8)
    assert nlen == 1 and raw[10:11] == b"x"
    code, ndim = struct.unpack_from("<II", raw, 11)
    assert code == 0 and ndim == 1  # f32, rank 1
    (dim0,) = struct.unpack_from("<Q", raw, 19)
    assert dim0 == 1
    (plen,) = struct.unpack_from("<Q", raw, 27)
    assert plen == 4
    assert struct.unpack_from("<f", raw, 35)[0] == 1.5


def test_rejects_unknown_dtype(tmp_path):
    with pytest.raises(ValueError):
        write_nbt(str(tmp_path / "bad.nbt"), {"c": np.array([1 + 2j])})


def test_rejects_bad_magic(tmp_path):
    p = tmp_path / "bad.nbt"
    p.write_bytes(b"XXXX\x00\x00\x00\x00")
    with pytest.raises(ValueError):
        read_nbt(str(p))


def test_order_preserved(tmp_path):
    path = str(tmp_path / "o.nbt")
    tensors = {k: np.zeros(1, np.float32) for k in ["z", "a", "m"]}
    write_nbt(path, tensors)
    assert list(read_nbt(path)) == ["z", "a", "m"]
