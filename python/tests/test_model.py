"""L2 model tests: shapes, sampled-vs-exact convergence, fused-path
equivalence, and the quantized input path."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import datagen, model as M
from compile.kernels import ref


@pytest.fixture(scope="module")
def tiny():
    """A miniature dataset (fast to trace) shared across tests."""
    spec = datagen.DatasetSpec(
        name="tiny", n=120, avg_deg=12.0, feats=16, classes=4, gamma=1.8,
        homophily=0.8, noise=1.0, scale="small", paper_nodes=0, paper_avg_deg=0.0,
    )
    return datagen.generate(spec, seed=1)


def _inputs(data, model):
    n = int(data["meta"][0])
    row_ptr = jnp.asarray(data["row_ptr"])
    col_ind = jnp.asarray(data["col_ind"])
    val = jnp.asarray(data["val_gcn"] if model == "gcn" else data["val_ones"])
    row_ids = jnp.asarray(
        np.repeat(np.arange(n, dtype=np.int32), np.diff(data["row_ptr"]))
    )
    x = jnp.asarray(data["feat"])
    return row_ptr, col_ind, val, row_ids, x


@pytest.mark.parametrize("model", ["gcn", "sage"])
def test_forward_shapes(tiny, model):
    n, nnz, feats, classes = (int(t) for t in tiny["meta"])
    init = M.init_gcn if model == "gcn" else M.init_sage
    params = init(jax.random.PRNGKey(0), feats, M.HIDDEN, classes)
    row_ptr, col_ind, val, row_ids, x = _inputs(tiny, model)
    logits = M.forward_exact(model, params, row_ptr, col_ind, val, row_ids, x)
    assert logits.shape == (n, classes)
    s = jnp.array([ref.AES], jnp.int32)
    logits2 = M.forward_sampled(model, params, row_ptr, col_ind, val, x, s, width=16)
    assert logits2.shape == (n, classes)


@pytest.mark.parametrize("model", ["gcn", "sage"])
def test_sampled_converges_to_exact_at_full_width(tiny, model):
    """W >= max degree => the sampled forward equals the exact forward."""
    _, _, feats, classes = (int(t) for t in tiny["meta"])
    init = M.init_gcn if model == "gcn" else M.init_sage
    params = init(jax.random.PRNGKey(1), feats, M.HIDDEN, classes)
    row_ptr, col_ind, val, row_ids, x = _inputs(tiny, model)
    wmax = int(np.diff(tiny["row_ptr"]).max())
    exact = M.forward_exact(model, params, row_ptr, col_ind, val, row_ids, x)
    for strategy in [ref.AFS, ref.SFS, ref.AES]:
        s = jnp.array([strategy], jnp.int32)
        sampled = M.forward_sampled(
            model, params, row_ptr, col_ind, val, x, s, width=wmax
        )
        np.testing.assert_allclose(
            np.asarray(sampled), np.asarray(exact), rtol=2e-3, atol=2e-3
        )


@pytest.mark.parametrize("model", ["gcn", "sage"])
def test_fused_path_equals_sampled_path(tiny, model):
    """forward_fused (per-layer in-kernel sampling, the GPU shape) must
    equal forward_sampled (sample-once) — the hash is deterministic."""
    _, _, feats, classes = (int(t) for t in tiny["meta"])
    init = M.init_gcn if model == "gcn" else M.init_sage
    params = init(jax.random.PRNGKey(2), feats, M.HIDDEN, classes)
    row_ptr, col_ind, val, _, x = _inputs(tiny, model)
    s = jnp.array([ref.AES], jnp.int32)
    a = M.forward_sampled(model, params, row_ptr, col_ind, val, x, s, width=16)
    b = M.forward_fused(model, params, row_ptr, col_ind, val, x, s, width=16)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)


def test_quantized_forward_close_to_f32(tiny):
    """INT8-input forward stays close to the f32 forward (Eq. 1/2 bound)."""
    _, _, feats, classes = (int(t) for t in tiny["meta"])
    params = M.init_gcn(jax.random.PRNGKey(3), feats, M.HIDDEN, classes)
    row_ptr, col_ind, val, _, x = _inputs(tiny, "gcn")
    q, lo, hi = ref.quantize(np.asarray(x))
    s = jnp.array([ref.AES], jnp.int32)
    f32_logits = M.forward_sampled("gcn", params, row_ptr, col_ind, val, x, s, width=16)
    q_logits = M.forward_sampled_quant(
        "gcn", params, row_ptr, col_ind, val, jnp.asarray(q),
        jnp.array([lo], jnp.float32), jnp.array([hi], jnp.float32), s, width=16,
    )
    # Same argmax for the overwhelming majority of nodes.
    agree = (
        np.argmax(np.asarray(f32_logits), 1) == np.argmax(np.asarray(q_logits), 1)
    ).mean()
    assert agree > 0.95, f"quantized argmax agreement {agree}"


def test_datagen_structure(tiny):
    n, nnz, feats, classes = (int(t) for t in tiny["meta"])
    row_ptr = tiny["row_ptr"]
    assert row_ptr[0] == 0 and row_ptr[-1] == nnz
    assert (np.diff(row_ptr) >= 1).all(), "every node has at least its self loop"
    col = tiny["col_ind"]
    assert col.min() >= 0 and col.max() < n
    # Self loops present: row i contains col i.
    for i in [0, n // 2, n - 1]:
        assert i in col[row_ptr[i]:row_ptr[i + 1]]
    # GCN normalization: val = 1/sqrt(d_i d_j) <= 1, > 0.
    assert (tiny["val_gcn"] > 0).all() and (tiny["val_gcn"] <= 1.0 + 1e-6).all()
    assert (tiny["val_ones"] == 1.0).all()
    # Features class-correlated: same-class mean distance < cross-class.
    feats_arr, labels = tiny["feat"], tiny["labels"]
    mus = np.stack([feats_arr[labels == c].mean(0) for c in range(classes)])
    d_same = np.linalg.norm(feats_arr - mus[labels], axis=1).mean()
    d_other = np.linalg.norm(feats_arr - mus[(labels + 1) % classes], axis=1).mean()
    assert d_same < d_other


def test_training_learns(tiny):
    from compile import train as T

    params, acc = T.train("gcn", tiny, epochs=40, seed=0)
    n_classes = int(tiny["meta"][3])
    assert acc > 2.0 / n_classes, f"accuracy {acc} no better than chance"
