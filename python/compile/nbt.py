"""NBT — a tiny named-binary-tensor container shared with the rust side.

One file holds an ordered set of named tensors. Layout (little endian):

    magic   b"NBTC"
    u32     tensor count
    per tensor:
        u16     name length, then name bytes (utf-8)
        u32     dtype code (0=f32, 1=i32, 2=u8, 3=i64, 4=f64, 5=i8)
        u32     ndim, then ndim * u64 dims
        u64     payload byte length, then raw row-major LE payload

The rust mirror lives in ``rust/src/tensor/nbt.rs``; both sides are covered
by round-trip tests against golden files.
"""

from __future__ import annotations

import struct

import numpy as np

MAGIC = b"NBTC"

_DTYPES: list[tuple[int, np.dtype]] = [
    (0, np.dtype("<f4")),
    (1, np.dtype("<i4")),
    (2, np.dtype("u1")),
    (3, np.dtype("<i8")),
    (4, np.dtype("<f8")),
    (5, np.dtype("i1")),
]
_CODE_OF = {dt: code for code, dt in _DTYPES}
_DTYPE_OF = {code: dt for code, dt in _DTYPES}


def write_nbt(path: str, tensors: dict[str, np.ndarray]) -> None:
    """Write ``tensors`` (name -> array) to ``path``; insertion order kept."""
    parts = [MAGIC, struct.pack("<I", len(tensors))]
    for name, arr in tensors.items():
        arr = np.ascontiguousarray(arr)
        dt = arr.dtype.newbyteorder("<") if arr.dtype.byteorder == ">" else arr.dtype
        code = _CODE_OF.get(np.dtype(dt))
        if code is None:
            raise ValueError(f"unsupported dtype {arr.dtype} for tensor {name!r}")
        nb = name.encode("utf-8")
        parts.append(struct.pack("<H", len(nb)))
        parts.append(nb)
        parts.append(struct.pack("<II", code, arr.ndim))
        parts.append(struct.pack(f"<{arr.ndim}Q", *arr.shape))
        payload = arr.tobytes()
        parts.append(struct.pack("<Q", len(payload)))
        parts.append(payload)
    with open(path, "wb") as f:
        f.write(b"".join(parts))


def read_nbt(path: str) -> dict[str, np.ndarray]:
    """Read a .nbt container back into name -> array (insertion order)."""
    with open(path, "rb") as f:
        buf = f.read()
    if buf[:4] != MAGIC:
        raise ValueError(f"{path}: bad magic {buf[:4]!r}")
    off = 4
    (count,) = struct.unpack_from("<I", buf, off)
    off += 4
    out: dict[str, np.ndarray] = {}
    for _ in range(count):
        (nlen,) = struct.unpack_from("<H", buf, off)
        off += 2
        name = buf[off : off + nlen].decode("utf-8")
        off += nlen
        code, ndim = struct.unpack_from("<II", buf, off)
        off += 8
        dims = struct.unpack_from(f"<{ndim}Q", buf, off)
        off += 8 * ndim
        (plen,) = struct.unpack_from("<Q", buf, off)
        off += 8
        dt = _DTYPE_OF[code]
        arr = np.frombuffer(buf, dtype=dt, count=plen // dt.itemsize, offset=off)
        out[name] = arr.reshape(dims)
        off += plen
    return out
