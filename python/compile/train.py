"""Build-time training of GCN / GraphSAGE on the synthetic datasets.

Mirrors the paper's protocol: models are trained with *exact* aggregation
(the DGL/cuSPARSE path), then inference runs over the *sampled* kernel —
AES-SpMM "leverages the tolerance of pre-trained GNN models to edge loss".
Full-batch Adam + cross-entropy; the selected model's exact-aggregation
test accuracy is the "ideal accuracy" baseline of Fig. 6.

No optax in this offline environment, so Adam is hand-rolled (15 lines).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M


def cross_entropy(logits, labels, mask):
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=1)[:, 0]
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def adam_update(params, grads, state, step, lr=0.01, b1=0.9, b2=0.999, eps=1e-8):
    m, v = state
    m = jax.tree.map(lambda a, g: b1 * a + (1 - b1) * g, m, grads)
    v = jax.tree.map(lambda a, g: b2 * a + (1 - b2) * g * g, v, grads)
    mh = jax.tree.map(lambda a: a / (1 - b1**step), m)
    vh = jax.tree.map(lambda a: a / (1 - b2**step), v)
    params = jax.tree.map(lambda p, a, b: p - lr * a / (jnp.sqrt(b) + eps), params, mh, vh)
    return params, (m, v)


def train(
    model_name: str,
    data: dict,
    *,
    epochs: int = 150,
    lr: float = 0.01,
    seed: int = 0,
    dropout: float = 0.5,
):
    """Train one model with input-feature dropout (the standard GCN/SAGE
    regularizer — without it GraphSAGE's self path memorizes the training
    half of the noisy synthetic graphs instead of using the aggregation).
    GraphSAGE also gets a longer schedule, as in the paper's protocol of
    training each model to its best test accuracy."""
    if model_name == "sage":
        epochs = max(epochs, 300)
    """Train one model; returns (params, ideal_test_accuracy)."""
    n, nnz, feats, classes = (int(t) for t in data["meta"])
    row_ptr = jnp.asarray(data["row_ptr"])
    col_ind = jnp.asarray(data["col_ind"])
    val = jnp.asarray(data["val_gcn"] if model_name == "gcn" else data["val_ones"])
    row_ids = jnp.asarray(
        np.repeat(np.arange(n, dtype=np.int32), np.diff(data["row_ptr"]))
    )
    x = jnp.asarray(data["feat"])
    labels = jnp.asarray(data["labels"].astype(np.int32))
    train_mask = jnp.asarray(data["train_mask"].astype(np.float32))
    test_mask = 1.0 - train_mask

    key = jax.random.PRNGKey(seed)
    init = M.init_gcn if model_name == "gcn" else M.init_sage
    params = init(key, feats, M.HIDDEN, classes)

    def loss_fn(p, dkey):
        # Input-feature dropout (inverted scaling), fresh mask per step.
        keep = jax.random.bernoulli(dkey, 1.0 - dropout, x.shape).astype(x.dtype)
        xd = x * keep / (1.0 - dropout)
        logits = M.forward_exact(model_name, p, row_ptr, col_ind, val, row_ids, xd)
        return cross_entropy(logits, labels, train_mask)

    @jax.jit
    def step(p, state, i, dkey):
        g = jax.grad(loss_fn)(p, dkey)
        return adam_update(p, g, state, i, lr=lr)

    state = (
        jax.tree.map(jnp.zeros_like, params),
        jax.tree.map(jnp.zeros_like, params),
    )
    dkey = jax.random.PRNGKey(seed ^ 0x5EED)
    for i in range(1, epochs + 1):
        dkey, sub = jax.random.split(dkey)
        params, state = step(params, state, jnp.float32(i), sub)

    logits = M.forward_exact(model_name, params, row_ptr, col_ind, val, row_ids, x)
    pred = jnp.argmax(logits, axis=1)
    acc = float(((pred == labels) * test_mask).sum() / test_mask.sum())
    return jax.device_get(params), acc
