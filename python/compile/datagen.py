"""Synthetic dataset generation — structural analogs of the paper's Table 2.

The real benchmark graphs (ogbn-arxiv, pubmed, cora, reddit, ogbn-proteins,
ogbn-products) are public but unavailable in this offline environment, so
we generate seeded degree-corrected stochastic-block-model graphs that
preserve the properties the paper's results actually depend on
(DESIGN.md §4):

* node count (scaled to interpret-mode-feasible sizes),
* average degree and degree skew (power-law for the "large" graphs) —
  these drive the Table 1 regime mix and the Fig. 5 sampling-rate CDF,
* community structure + class-correlated features — these make sampled
  aggregation *approximately* correct, so accuracy degrades smoothly with
  the sampling rate, as in the paper,
* per-node feature noise strong enough that aggregation genuinely matters
  (an MLP on raw features underperforms the GNN).

Every dataset is a dict of numpy arrays written to ``artifacts/data`` as a
.nbt container consumed by both the AOT pipeline and the rust runtime.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class DatasetSpec:
    name: str
    n: int
    avg_deg: float
    feats: int
    classes: int
    gamma: float  # power-law exponent for expected degrees (0 => uniform)
    homophily: float  # probability an edge endpoint stays intra-community
    noise: float  # per-node feature noise scale
    scale: str  # "small" | "large" (paper's grouping)
    paper_nodes: int
    paper_avg_deg: float
    # Fraction of nodes whose id follows community order (the rest are
    # scattered). Real graphs have *partial* id-community correlation:
    # enough that SFS's prefix sampling is biased, but not so much that a
    # short consecutive run (AES's N>1 granularity) is single-community.
    id_locality: float = 0.65


# Scaled analogs of Table 2. avg_deg for reddit/proteins is scaled with n
# (keeping deg >> W preserves the R-regime mix that drives the results).
SPECS: dict[str, DatasetSpec] = {
    s.name: s
    for s in [
        DatasetSpec("cora", 2708, 3.9, 128, 7, 0.0, 0.90, 0.8, "small", 2708, 3.9),
        DatasetSpec("pubmed", 4096, 4.5, 128, 3, 0.0, 0.88, 0.9, "small", 19717, 4.5),
        DatasetSpec("arxiv", 4096, 13.7, 128, 40, 2.2, 0.75, 1.2, "small", 169343, 13.7),
        DatasetSpec("reddit", 2048, 160.0, 64, 41, 2.0, 0.70, 1.4, "large", 232965, 493.0),
        DatasetSpec("proteins", 2048, 180.0, 64, 8, 1.9, 0.65, 1.7, "large", 132534, 597.0),
        DatasetSpec("products", 8192, 50.0, 64, 47, 2.1, 0.70, 1.2, "large", 2449029, 50.5),
    ]
}

SMALL = [n for n, s in SPECS.items() if s.scale == "small"]
LARGE = [n for n, s in SPECS.items() if s.scale == "large"]


def _expected_degrees(spec: DatasetSpec, rng: np.random.Generator) -> np.ndarray:
    """Power-law (or mildly skewed) expected degree sequence with the target mean."""
    if spec.gamma > 0:
        # Pareto-ish: w_i ~ (i + i0)^(-1/(gamma-1)), the Chung-Lu classic.
        ranks = np.arange(1, spec.n + 1, dtype=np.float64)
        w = (ranks + 10.0) ** (-1.0 / (spec.gamma - 1.0))
        rng.shuffle(w)
    else:
        # Small citation nets: lognormal-ish mild skew.
        w = rng.lognormal(mean=0.0, sigma=0.6, size=spec.n)
    w *= spec.avg_deg * spec.n / w.sum()
    return np.maximum(w, 0.25)


def generate(spec: DatasetSpec, seed: int = 0) -> dict[str, np.ndarray]:
    """Generate one dataset; returns the tensors written to its .nbt."""
    rng = np.random.default_rng(seed ^ hash(spec.name) % (1 << 32))
    n = spec.n

    # Communities mostly occupy contiguous node-id ranges, as in real
    # benchmark graphs where neighbor lists have id locality (crawl order,
    # time, category). The sorted component makes SFS's prefix-of-the-row
    # sampling *biased* — the paper's "concentrated edge distribution"
    # failure — while the scattered fraction keeps short consecutive runs
    # (AES's N-element granularity) class-diverse, as in real graphs.
    comm = np.sort(rng.integers(0, spec.classes, n)).astype(np.int32)
    scattered = np.flatnonzero(rng.random(n) > spec.id_locality)
    comm[scattered] = rng.permutation(comm[scattered])
    w = _expected_degrees(spec, rng)
    p = w / w.sum()

    # Degree-corrected SBM edge sampling: draw u globally weight-biased,
    # then v intra-community with prob `homophily`, else globally.
    def sample_pairs(m):
        u = rng.choice(n, size=m, p=p)
        intra = rng.random(m) < spec.homophily
        v = np.empty(m, dtype=np.int64)
        v[~intra] = rng.choice(n, size=int((~intra).sum()), p=p)
        # Community-restricted draws, vectorized per community.
        for c in range(spec.classes):
            mask = intra & (comm[u] == c)
            k = int(mask.sum())
            if k == 0:
                continue
            members = np.flatnonzero(comm == c)
            pc = p[members] / p[members].sum()
            v[mask] = members[rng.choice(members.size, size=k, p=pc)]
        keep = u != v
        return u[keep], v[keep]

    # Skewed weights collapse many duplicate (hub, hub) pairs, so sample
    # in rounds until the deduplicated edge count reaches the target —
    # otherwise heavy-tailed graphs land far below their Table 2 degree.
    target = int(spec.avg_deg * n / 2)
    m = target
    eid = np.empty(0, dtype=np.int64)
    for _ in range(6):
        u, v = sample_pairs(m)
        lo, hi = np.minimum(u, v), np.maximum(u, v)
        eid = np.unique(np.concatenate([eid, lo.astype(np.int64) * n + hi]))
        if eid.size >= int(0.95 * target):
            break
        m = max((target - eid.size) * 2, 1024)  # oversample the deficit

    lo = (eid // n).astype(np.int64)
    hi = (eid % n).astype(np.int64)
    # Undirected + self loops (GCN's Â = D^-1/2 (A+I) D^-1/2).
    src = np.concatenate([lo, hi, np.arange(n)])
    dst = np.concatenate([hi, lo, np.arange(n)])
    eid = np.unique(src.astype(np.int64) * n + dst)
    src = (eid // n).astype(np.int32)
    dst = (eid % n).astype(np.int32)

    # CSR (rows sorted by construction of np.unique on src*n+dst).
    deg = np.bincount(src, minlength=n)
    row_ptr = np.zeros(n + 1, dtype=np.int32)
    row_ptr[1:] = np.cumsum(deg)
    col_ind = dst

    # GCN-normalized values and all-ones values on the same structure.
    dsq = 1.0 / np.sqrt(np.maximum(deg, 1).astype(np.float64))
    val_gcn = (dsq[src] * dsq[dst]).astype(np.float32)
    val_ones = np.ones_like(val_gcn)

    # Class-correlated features: mu[c] + noise, normalized rows.
    mu = rng.standard_normal((spec.classes, spec.feats)).astype(np.float32)
    mu /= np.linalg.norm(mu, axis=1, keepdims=True)
    x = mu[comm] + spec.noise * rng.standard_normal((n, spec.feats)).astype(np.float32)

    # 50/50 train/test split.
    order = rng.permutation(n)
    train_mask = np.zeros(n, dtype=np.uint8)
    train_mask[order[: n // 2]] = 1

    return {
        "row_ptr": row_ptr.astype(np.int32),
        "col_ind": col_ind.astype(np.int32),
        "val_gcn": val_gcn,
        "val_ones": val_ones,
        "feat": x.astype(np.float32),
        "labels": comm,
        "train_mask": train_mask,
        "meta": np.array(
            [n, int(row_ptr[-1]), spec.feats, spec.classes], dtype=np.int64
        ),
    }
