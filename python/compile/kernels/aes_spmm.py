"""Pallas kernels for AES-SpMM (Algorithm 1 of the paper).

Three kernels, all authored for TPU but lowered with ``interpret=True`` so
the resulting HLO runs on any PJRT backend (the rust CPU client in this
repo). See DESIGN.md §Hardware-Adaptation for the CUDA→TPU mapping: the
paper's shared-memory row buffer of width W becomes a VMEM-resident ELL
tile ``(rows, W)``; per-thread sampling becomes a vectorized index matrix;
the per-thread feature loop becomes a lane-parallel ``fori_loop`` over W.

* ``aes_sample``  — Alg. 1 lines 5–14: adaptive edge sampling into ELL.
* ``spmm_ell``    — Alg. 1 lines 16–19: multiply the sampled tile with B.
* ``aes_spmm``    — the fused single-launch kernel (paper's actual kernel).

The ``strategy`` argument is a runtime int32 scalar (shape ``(1,)``):
0 = AFS, 1 = SFS, 2 = AES — so one compiled artifact serves all three
sampling schemes (the index math is branch-free integer arithmetic).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import PRIME

# CPU PJRT cannot execute Mosaic custom-calls; interpret mode lowers the
# kernel body to portable HLO. Real-TPU builds flip this to False.
INTERPRET = True


def _slot_plan(row_ptr, strategy, width: int):
    """Vectorized Table 1 + Eq. 3: per-(row, slot) source index and mask.

    Returns ``(src [n,W] i32, valid [n,W] bool, slots [n,1] i32)`` where
    ``src`` indexes into the flat CSR col/val arrays (clamped-safe for
    invalid slots).
    """
    rp = row_ptr.astype(jnp.int32)
    base = rp[:-1][:, None]  # [n,1]
    nnz = (rp[1:] - rp[:-1])[:, None]  # [n,1]
    strat = strategy[0]

    w = jnp.int32(width)
    weff = jnp.minimum(nnz, w)

    # Table 1 (AES): thresholds on R = row_nnz / W, integer form.
    n_aes = jnp.where(
        nnz <= 2 * w,
        w // 4,
        jnp.where(nnz <= 36 * w, w // 8, jnp.where(nnz <= 54 * w, w // 16, w // 32)),
    )
    cnt_aes = jnp.where(
        nnz <= 2 * w,
        4,
        jnp.where(nnz <= 36 * w, 8, jnp.where(nnz <= 54 * w, 16, 32)),
    )
    n_aes = jnp.maximum(n_aes, 1)
    cnt_aes = jnp.minimum(cnt_aes, w)

    # Strategy select: AFS (N=1, cnt=W), SFS (N=W_eff, cnt=1), AES (table).
    n_sel = jnp.where(strat == 0, 1, jnp.where(strat == 1, weff, n_aes))
    cnt_sel = jnp.where(strat == 0, w, jnp.where(strat == 1, 1, cnt_aes))
    # Universal fast path: row fits in shared memory -> take everything.
    n_sel = jnp.where(nnz <= w, nnz, n_sel)
    cnt_sel = jnp.where(nnz <= w, 1, cnt_sel)

    slots = jnp.minimum(n_sel * cnt_sel, w)  # [n,1]

    k = jnp.arange(width, dtype=jnp.int32)[None, :]  # [1,W]
    cnt_safe = jnp.maximum(cnt_sel, 1)
    s = k % cnt_safe  # sample index
    j = k // cnt_safe  # offset within the consecutive run
    rng = jnp.maximum(nnz - n_sel + 1, 1)
    start = (s * jnp.int32(PRIME)) % rng  # Eq. 3
    src = base + start + j
    valid = k < slots
    src = jnp.where(valid, src, base)  # clamp padding to a safe index
    return src, valid, slots


def _sample_kernel(rp_ref, col_ref, val_ref, strat_ref, ev_ref, ec_ref, sl_ref, *, width):
    src, valid, slots = _slot_plan(rp_ref[...], strat_ref[...], width)
    if col_ref.shape[0] == 0:  # empty graph: nothing to gather (static)
        ev_ref[...] = jnp.zeros(ev_ref.shape, jnp.float32)
        ec_ref[...] = jnp.zeros(ec_ref.shape, jnp.int32)
        sl_ref[...] = slots[:, 0]
        return
    col = col_ref[...]
    val = val_ref[...]
    ev_ref[...] = jnp.where(valid, jnp.take(val, src, axis=0), 0.0)
    ec_ref[...] = jnp.where(valid, jnp.take(col, src, axis=0), 0)
    sl_ref[...] = slots[:, 0]


def aes_sample(row_ptr, col_ind, val, strategy, *, width: int):
    """Sampled ELL form of the CSR matrix: (ell_val, ell_col, slots)."""
    n = row_ptr.shape[0] - 1
    if col_ind.shape[0] == 0:
        # Empty graph: no pallas launch (the interpreter cannot pad
        # zero-length blocks); the sampled form is trivially all-padding.
        return (
            jnp.zeros((n, width), jnp.float32),
            jnp.zeros((n, width), jnp.int32),
            jnp.zeros((n,), jnp.int32),
        )
    return pl.pallas_call(
        functools.partial(_sample_kernel, width=width),
        out_shape=(
            jax.ShapeDtypeStruct((n, width), jnp.float32),
            jax.ShapeDtypeStruct((n, width), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
        ),
        interpret=INTERPRET,
    )(row_ptr, col_ind, val, strategy)


def _ell_matmul(ell_val, ell_col, b):
    """acc[i,:] = sum_k ell_val[i,k] * B[ell_col[i,k],:] via a W-step loop.

    On real TPU each step is a row gather of the feature block (one-hot ×
    B on the MXU); ``fori_loop`` keeps the lowered HLO compact (no
    unrolling) for the W values we compile.
    """
    n = ell_val.shape[0]
    f = b.shape[1]

    def body(k, acc):
        v = jax.lax.dynamic_slice_in_dim(ell_val, k, 1, axis=1)  # [n,1]
        c = jax.lax.dynamic_slice_in_dim(ell_col, k, 1, axis=1)[:, 0]  # [n]
        return acc + v * jnp.take(b, c, axis=0)

    return jax.lax.fori_loop(0, ell_val.shape[1], body, jnp.zeros((n, f), b.dtype))


def _spmm_ell_kernel(ev_ref, ec_ref, b_ref, o_ref):
    o_ref[...] = _ell_matmul(ev_ref[...], ec_ref[...], b_ref[...])


def spmm_ell(ell_val, ell_col, b):
    """SpMM over a pre-sampled ELL tile (Alg. 1 lines 16–19)."""
    n = ell_val.shape[0]
    return pl.pallas_call(
        _spmm_ell_kernel,
        out_shape=jax.ShapeDtypeStruct((n, b.shape[1]), b.dtype),
        interpret=INTERPRET,
    )(ell_val, ell_col, b)


def _fused_kernel(rp_ref, col_ref, val_ref, b_ref, strat_ref, o_ref, *, width, mean):
    src, valid, slots = _slot_plan(rp_ref[...], strat_ref[...], width)
    if col_ref.shape[0] == 0:  # empty graph: aggregation is all zeros
        o_ref[...] = jnp.zeros(o_ref.shape, b_ref.dtype)
        return
    ell_val = jnp.where(valid, jnp.take(val_ref[...], src, axis=0), 0.0)
    ell_col = jnp.where(valid, jnp.take(col_ref[...], src, axis=0), 0)
    acc = _ell_matmul(ell_val, ell_col, b_ref[...])
    if mean:
        acc = acc / jnp.maximum(slots, 1).astype(acc.dtype)
    o_ref[...] = acc


def aes_spmm(row_ptr, col_ind, val, b, strategy, *, width: int, mean: bool = False):
    """Fused sample→multiply kernel — the paper's single-launch AES-SpMM.

    ``mean=True`` turns the row reduction into a mean over valid slots
    (GraphSAGE aggregator); ``mean=False`` is the plain weighted sum (GCN).
    """
    n = row_ptr.shape[0] - 1
    if col_ind.shape[0] == 0:  # empty graph — aggregation is zero
        return jnp.zeros((n, b.shape[1]), b.dtype)
    return pl.pallas_call(
        functools.partial(_fused_kernel, width=width, mean=mean),
        out_shape=jax.ShapeDtypeStruct((n, b.shape[1]), b.dtype),
        interpret=INTERPRET,
    )(row_ptr, col_ind, val, b, strategy)
