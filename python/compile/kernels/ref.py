"""Pure-numpy/jnp oracles for the AES-SpMM kernels.

This module pins the *exact* semantics of the paper's adaptive edge
sampling (Table 1 + Eq. 3 + Algorithm 1) in slow, obviously-correct code.
The Pallas kernels (``aes_spmm.py``) and the rust planner
(``rust/src/sampling``) must match these bit-for-bit on integer outputs and
to float tolerance on products.

Strategy encoding (runtime scalar in the compiled artifacts):
    0 = AFS  (ES-SpMM accuracy-first: N=1, cnt=W)
    1 = SFS  (ES-SpMM speed-first:   N=W, cnt=1 -> first W elements)
    2 = AES  (the paper's adaptive Table 1)
"""

from __future__ import annotations

import numpy as np

PRIME = 1429  # Eq. 3's prime_num

AFS, SFS, AES = 0, 1, 2
STRATEGY_NAMES = {AFS: "afs", SFS: "sfs", AES: "aes"}


def strategy_params(row_nnz: int, width: int, strategy: int) -> tuple[int, int]:
    """Return (N, sample_cnt) for one row.

    ``N`` is the number of consecutive elements per sample, ``sample_cnt``
    the number of samples. Table 1 of the paper, plus the implementation
    clamps it calls out (N >= 1, sample_cnt <= W), plus the universal
    row_nnz <= W fast path ("all elements in the row are selected").
    """
    if row_nnz <= width:
        return row_nnz, 1
    if strategy == AFS:
        return 1, width
    if strategy == SFS:
        return width, 1
    if strategy != AES:
        raise ValueError(f"unknown strategy {strategy}")
    # Table 1: thresholds on R = row_nnz / W, expressed integrally.
    if row_nnz <= 2 * width:
        n, cnt = width // 4, 4
    elif row_nnz <= 36 * width:
        n, cnt = width // 8, 8
    elif row_nnz <= 54 * width:
        n, cnt = width // 16, 16
    else:
        n, cnt = width // 32, 32
    return max(n, 1), min(cnt, width)


def start_index(sample_idx: int, row_nnz: int, n: int) -> int:
    """Eq. 3: start_ind = (i * prime) mod (row_nnz - N + 1)."""
    return (sample_idx * PRIME) % (row_nnz - n + 1)


def sample_row(row_nnz: int, width: int, strategy: int) -> np.ndarray:
    """Return the within-row source offsets for every ELL slot of one row.

    Output shape ``(width,)``; invalid (padding) slots hold -1. Slot layout
    follows Algorithm 1: sample ``s`` writes its ``j``-th consecutive
    element into slot ``s + j * sample_cnt``.
    """
    n, cnt = strategy_params(row_nnz, width, strategy)
    slots = min(n * cnt, width)
    out = np.full(width, -1, dtype=np.int64)
    for k in range(slots):
        s = k % cnt
        j = k // cnt
        out[k] = start_index(s, row_nnz, n) + j
    return out


def sample_ell(
    row_ptr: np.ndarray,
    col_ind: np.ndarray,
    val: np.ndarray,
    width: int,
    strategy: int,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Build the sampled ELL form of a CSR matrix.

    Returns ``(ell_val [n,W] f32, ell_col [n,W] i32, slots [n] i32)`` where
    padding slots have val 0 / col 0, and ``slots[i]`` counts valid slots.
    """
    n_rows = row_ptr.shape[0] - 1
    ell_val = np.zeros((n_rows, width), dtype=np.float32)
    ell_col = np.zeros((n_rows, width), dtype=np.int32)
    slots = np.zeros(n_rows, dtype=np.int32)
    for i in range(n_rows):
        base = int(row_ptr[i])
        nnz = int(row_ptr[i + 1]) - base
        offs = sample_row(nnz, width, strategy)
        valid = offs >= 0
        slots[i] = int(valid.sum())
        src = base + offs[valid]
        ell_val[i, : slots[i]] = val[src]
        ell_col[i, : slots[i]] = col_ind[src]
    return ell_val, ell_col, slots


def spmm_ell(ell_val: np.ndarray, ell_col: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Dense output C[i,:] = sum_k ell_val[i,k] * B[ell_col[i,k],:]."""
    n, width = ell_val.shape
    out = np.zeros((n, b.shape[1]), dtype=np.float32)
    for k in range(width):
        out += ell_val[:, k : k + 1] * b[ell_col[:, k], :]
    return out


def aes_spmm(row_ptr, col_ind, val, b, width, strategy, mean=False):
    """Fused oracle: sample then multiply (Algorithm 1 end to end).

    ``mean=True`` divides each row by its valid slot count (GraphSAGE's
    mean aggregator over the sampled neighborhood).
    """
    ell_val, ell_col, slots = sample_ell(row_ptr, col_ind, val, width, strategy)
    out = spmm_ell(ell_val, ell_col, b)
    if mean:
        out /= np.maximum(slots, 1)[:, None].astype(np.float32)
    return out


def csr_spmm(row_ptr, col_ind, val, b):
    """Exact (non-sampled) CSR SpMM — the cuSPARSE-role oracle."""
    n = row_ptr.shape[0] - 1
    out = np.zeros((n, b.shape[1]), dtype=np.float32)
    for i in range(n):
        lo, hi = int(row_ptr[i]), int(row_ptr[i + 1])
        for e in range(lo, hi):
            out[i] += val[e] * b[col_ind[e]]
    return out


def quantize(x: np.ndarray, bits: int = 8) -> tuple[np.ndarray, float, float]:
    """Eq. 1: scalar quantization of a feature tensor to ``bits`` levels."""
    x_min = float(x.min())
    x_max = float(x.max())
    levels = (1 << bits) - 1
    scale = (x_max - x_min) or 1.0
    q = np.floor((x - x_min) / scale * levels)
    q = np.clip(q, 0, levels)
    return q.astype(np.uint8 if bits <= 8 else np.uint16), x_min, x_max


def dequantize(q: np.ndarray, x_min: float, x_max: float, bits: int = 8) -> np.ndarray:
    """Eq. 2: recover approximate features from quantized values."""
    levels = (1 << bits) - 1
    return (q.astype(np.float32) * ((x_max - x_min) / levels) + x_min).astype(
        np.float32
    )


def sampling_rate(row_ptr: np.ndarray, width: int, strategy: int) -> float:
    """Fraction of edges kept by sampling (Fig. 5's per-graph statistic).

    Counts *slots* (draws), capped at row_nnz per row so overlapping draws
    never report a rate above 1.
    """
    deg = np.diff(row_ptr).astype(np.int64)
    kept = 0
    for nnz in deg:
        n, cnt = strategy_params(int(nnz), width, strategy)
        kept += min(min(n * cnt, width), int(nnz))
    total = int(deg.sum())
    return kept / total if total else 1.0
