"""Pallas kernel for on-device feature dequantization (Eq. 2).

The quantized path loads node features as u8 (4x fewer bytes over the
host→device link than f32), then this kernel recovers approximate f32
features before the GNN forward pass. The paper measures ~2 ms for this
stage on GPU because it is perfectly elementwise; on TPU it is a pure VPU
kernel, one lane per feature column.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .aes_spmm import INTERPRET

LEVELS = 255.0  # 2^8 - 1 for the INT8 path


def _dequant_kernel(q_ref, lo_ref, hi_ref, o_ref):
    lo = lo_ref[0]
    hi = hi_ref[0]
    scale = (hi - lo) / LEVELS
    o_ref[...] = q_ref[...].astype(jnp.float32) * scale + lo


def dequant(q, x_min, x_max):
    """Dequantize ``q`` (u8 [n,f]) to f32 given scalar bounds (shape (1,))."""
    return pl.pallas_call(
        _dequant_kernel,
        out_shape=jax.ShapeDtypeStruct(q.shape, jnp.float32),
        interpret=INTERPRET,
    )(q, x_min, x_max)
