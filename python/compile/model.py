"""Layer-2: GCN and GraphSAGE forward passes in JAX, calling the L1 kernels.

Three aggregation backends share one model body:

* ``sampled``  — the AES-SpMM path: ``aes_sample`` once, ``spmm_ell`` per
  layer. The Eq. 3 hash is deterministic, so re-sampling inside each layer
  (as the fused GPU kernel does per launch) would select the identical
  edge set; sampling once is semantically equal and cheaper (DESIGN.md
  §Perf L2).
* ``exact``    — segment-sum CSR SpMM; the cuSPARSE-role baseline and the
  aggregation used for build-time training.
* ``fused``    — the single-launch ``aes_spmm`` kernel, used by kernel
  micro-benches and the fidelity tests.

Models mirror the paper's setup (2-layer GCN [21], 2-layer mean-aggregator
GraphSAGE [22]); weights are pytrees of plain jnp arrays so they can be
shipped to rust as .nbt tensors and passed to the AOT artifact as runtime
parameters.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.aes_spmm import aes_sample, aes_spmm, spmm_ell
from .kernels.dequant import dequant

HIDDEN = 64


# --------------------------------------------------------------------------
# Aggregation backends
# --------------------------------------------------------------------------


def agg_exact(row_ptr, col_ind, val, row_ids, x):
    """Exact CSR SpMM via segment-sum: out[i] = sum_e val[e] * x[col[e]]."""
    n = row_ptr.shape[0] - 1
    contrib = val[:, None] * jnp.take(x, col_ind, axis=0)
    return jax.ops.segment_sum(contrib, row_ids, num_segments=n)


def agg_exact_mean(row_ptr, col_ind, row_ids, x):
    """Exact neighbor mean (GraphSAGE aggregator, training path)."""
    n = row_ptr.shape[0] - 1
    deg = (row_ptr[1:] - row_ptr[:-1]).astype(x.dtype)
    s = jax.ops.segment_sum(jnp.take(x, col_ind, axis=0), row_ids, num_segments=n)
    return s / jnp.maximum(deg, 1.0)[:, None]


# --------------------------------------------------------------------------
# Parameter initialization
# --------------------------------------------------------------------------


def init_gcn(key, in_dim, hidden, classes):
    k0, k1 = jax.random.split(key)
    s0 = jnp.sqrt(2.0 / in_dim)
    s1 = jnp.sqrt(2.0 / hidden)
    return {
        "w0": jax.random.normal(k0, (in_dim, hidden), jnp.float32) * s0,
        "b0": jnp.zeros((hidden,), jnp.float32),
        "w1": jax.random.normal(k1, (hidden, classes), jnp.float32) * s1,
        "b1": jnp.zeros((classes,), jnp.float32),
    }


def init_sage(key, in_dim, hidden, classes):
    k0, k1, k2, k3 = jax.random.split(key, 4)
    s0 = jnp.sqrt(2.0 / in_dim)
    s1 = jnp.sqrt(2.0 / hidden)
    return {
        "w0_self": jax.random.normal(k0, (in_dim, hidden), jnp.float32) * s0,
        "w0_neigh": jax.random.normal(k1, (in_dim, hidden), jnp.float32) * s0,
        "b0": jnp.zeros((hidden,), jnp.float32),
        "w1_self": jax.random.normal(k2, (hidden, classes), jnp.float32) * s1,
        "w1_neigh": jax.random.normal(k3, (hidden, classes), jnp.float32) * s1,
        "b1": jnp.zeros((classes,), jnp.float32),
    }


# Deterministic parameter order for the AOT artifact input signature.
GCN_PARAM_ORDER = ["w0", "b0", "w1", "b1"]
SAGE_PARAM_ORDER = ["w0_self", "w0_neigh", "b0", "w1_self", "w1_neigh", "b1"]


def param_order(model: str):
    return GCN_PARAM_ORDER if model == "gcn" else SAGE_PARAM_ORDER


# --------------------------------------------------------------------------
# Model bodies, generic over the aggregation closure
# --------------------------------------------------------------------------


def gcn_forward(params, x, agg):
    """2-layer GCN: logits = Â relu(Â X W0 + b0) W1 + b1 (Kipf & Welling)."""
    h = jax.nn.relu(agg(x @ params["w0"]) + params["b0"])
    return agg(h @ params["w1"]) + params["b1"]


def sage_forward(params, x, agg_mean):
    """2-layer mean-aggregator GraphSAGE: h' = relu(W_s h + W_n mean(N(h)))."""
    m = agg_mean(x)
    h = jax.nn.relu(x @ params["w0_self"] + m @ params["w0_neigh"] + params["b0"])
    m = agg_mean(h)
    return h @ params["w1_self"] + m @ params["w1_neigh"] + params["b1"]


# --------------------------------------------------------------------------
# Entry points used by training and AOT lowering
# --------------------------------------------------------------------------


def forward_exact(model, params, row_ptr, col_ind, val, row_ids, x):
    """Exact-aggregation forward; the training path and cuSPARSE-role artifact."""
    if model == "gcn":
        return gcn_forward(params, x, lambda h: agg_exact(row_ptr, col_ind, val, row_ids, h))
    return sage_forward(params, x, lambda h: agg_exact_mean(row_ptr, col_ind, row_ids, h))


def forward_exact_nrows(model, params, n, col_ind, val, row_ids, x):
    """Exact forward without `row_ptr` in the signature.

    The AOT baseline artifact uses this variant: for GCN, `row_ptr`'s
    *values* are never read (only its length), so XLA prunes the parameter
    from the compiled module and the rust-side positional inputs would
    misalign. Degrees come from a segment-sum over `row_ids` instead.
    """
    agg = lambda h: jax.ops.segment_sum(
        val[:, None] * jnp.take(h, col_ind, axis=0), row_ids, num_segments=n
    )
    if model == "gcn":
        return gcn_forward(params, x, agg)
    # SAGE receives val_ones, so segment_sum(val) IS the degree — this
    # keeps `val` value-used (XLA would prune an ones_like-only operand).
    deg = jax.ops.segment_sum(val, row_ids, num_segments=n)

    def agg_mean(h):
        return agg(h) / jnp.maximum(deg, 1.0)[:, None]

    return sage_forward(params, x, agg_mean)


def forward_sampled(model, params, row_ptr, col_ind, val, x, strategy, *, width):
    """AES/AFS/SFS-sampled forward — the artifact behind `model_*.hlo.txt`.

    Samples the graph once with the L1 Pallas kernel, then runs both GNN
    layers over the resulting ELL tile.
    """
    ell_val, ell_col, slots = aes_sample(row_ptr, col_ind, val, strategy, width=width)
    if model == "gcn":
        return gcn_forward(params, x, lambda h: spmm_ell(ell_val, ell_col, h))
    inv = 1.0 / jnp.maximum(slots, 1).astype(jnp.float32)

    def agg_mean(h):
        return spmm_ell(ell_val, ell_col, h) * inv[:, None]

    return sage_forward(params, x, agg_mean)


def forward_sampled_quant(
    model, params, row_ptr, col_ind, val, xq, x_min, x_max, strategy, *, width
):
    """Quantized-input variant: dequantize on device (Eq. 2), then forward."""
    x = dequant(xq, x_min, x_max)
    return forward_sampled(model, params, row_ptr, col_ind, val, x, strategy, width=width)


def forward_fused(model, params, row_ptr, col_ind, val, x, strategy, *, width):
    """Forward through the fused single-launch aes_spmm kernel (per layer).

    Mirrors the paper's GPU execution exactly (sampling re-runs in every
    kernel launch); used by fidelity tests to confirm it equals
    ``forward_sampled``.
    """
    if model == "gcn":
        agg = lambda h: aes_spmm(row_ptr, col_ind, val, h, strategy, width=width)
        return gcn_forward(params, x, agg)
    agg = lambda h: aes_spmm(row_ptr, col_ind, val, h, strategy, width=width, mean=True)
    return sage_forward(params, x, agg)
