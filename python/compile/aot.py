"""AOT pipeline: datasets → trained weights → HLO-text artifacts.

Run as ``python -m compile.aot --out-dir ../artifacts`` (the Makefile's
``artifacts`` target). Python never runs again after this: the rust
coordinator loads the HLO text through PJRT and the .nbt tensors directly.

Interchange format is HLO **text**, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which the xla crate's
pinned xla_extension 0.5.1 rejects; the text parser reassigns ids (see
/opt/xla-example/README.md).

Artifact matrix (DESIGN.md §2):
    model_{m}_{d}_w{W}.hlo.txt    sampled forward, strategy runtime scalar
    qmodel_{m}_{d}_w{W}.hlo.txt   INT8-feature variant (on-device dequant)
    baseline_{m}_{d}.hlo.txt      exact segment-sum forward (cuSPARSE role)
    data_{d}.nbt                  graph + features (+ quantized) + labels
    weights_{m}_{d}.nbt           trained parameters
    manifest.json                 input signatures + ideal accuracies
"""

from __future__ import annotations

import argparse
import functools
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import datagen, model as M, train as T
from .kernels import ref
from .nbt import read_nbt, write_nbt

WIDTHS = [16, 32, 64, 128, 256]
MODELS = ["gcn", "sage"]


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (ids reassigned by the parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _sig(entries):
    """Manifest input signature: list of {name, shape, dtype}."""
    return [
        {"name": n, "shape": list(map(int, s.shape)), "dtype": str(s.dtype)}
        for n, s in entries
    ]


def lower_artifacts_for(model_name, ds_name, data, out_dir, widths=WIDTHS):
    """Lower baseline + sampled + quantized artifacts for one (model, dataset)."""
    n, nnz, feats, classes = (int(t) for t in data["meta"])
    val_key = "val_gcn" if model_name == "gcn" else "val_ones"
    porder = M.param_order(model_name)
    # Parameter shapes from a throwaway init (values irrelevant to lowering).
    p0 = (M.init_gcn if model_name == "gcn" else M.init_sage)(
        jax.random.PRNGKey(0), feats, M.HIDDEN, classes
    )
    pspecs = [(k, _spec(p0[k].shape, jnp.float32)) for k in porder]

    csr = [
        ("row_ptr", _spec((n + 1,), jnp.int32)),
        ("col_ind", _spec((nnz,), jnp.int32)),
        (val_key, _spec((nnz,), jnp.float32)),
    ]
    entries = {}

    # --- baseline (exact, segment-sum; plays cuSPARSE) -----------------
    # No row_ptr input: its values are dead in the GCN graph and XLA would
    # prune the parameter (see model.forward_exact_nrows docstring).
    def fwd_exact(col_ind, val, row_ids, x, *ps):
        params = dict(zip(porder, ps))
        return (M.forward_exact_nrows(model_name, params, n, col_ind, val, row_ids, x),)

    base_in = csr[1:] + [
        ("row_ids", _spec((nnz,), jnp.int32)),
        ("feat", _spec((n, feats), jnp.float32)),
    ] + pspecs
    lowered = jax.jit(fwd_exact).lower(*[s for _, s in base_in])
    name = f"baseline_{model_name}_{ds_name}"
    with open(os.path.join(out_dir, f"{name}.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))
    entries[name] = {"inputs": _sig(base_in), "kind": "baseline"}

    for w in widths:
        # --- sampled (AES/AFS/SFS via runtime strategy scalar) ---------
        def fwd_sampled(row_ptr, col_ind, val, x, strategy, *ps, _w=w):
            params = dict(zip(porder, ps))
            return (
                M.forward_sampled(
                    model_name, params, row_ptr, col_ind, val, x, strategy, width=_w
                ),
            )

        samp_in = csr + [
            ("feat", _spec((n, feats), jnp.float32)),
            ("strategy", _spec((1,), jnp.int32)),
        ] + pspecs
        lowered = jax.jit(fwd_sampled).lower(*[s for _, s in samp_in])
        name = f"model_{model_name}_{ds_name}_w{w}"
        with open(os.path.join(out_dir, f"{name}.hlo.txt"), "w") as f:
            f.write(to_hlo_text(lowered))
        entries[name] = {"inputs": _sig(samp_in), "kind": "sampled", "width": w}

        # --- quantized input variant ------------------------------------
        def fwd_q(row_ptr, col_ind, val, xq, qmin, qmax, strategy, *ps, _w=w):
            params = dict(zip(porder, ps))
            return (
                M.forward_sampled_quant(
                    model_name, params, row_ptr, col_ind, val, xq, qmin, qmax,
                    strategy, width=_w,
                ),
            )

        q_in = csr + [
            ("featq", _spec((n, feats), jnp.uint8)),
            ("qmin", _spec((1,), jnp.float32)),
            ("qmax", _spec((1,), jnp.float32)),
            ("strategy", _spec((1,), jnp.int32)),
        ] + pspecs
        lowered = jax.jit(fwd_q).lower(*[s for _, s in q_in])
        name = f"qmodel_{model_name}_{ds_name}_w{w}"
        with open(os.path.join(out_dir, f"{name}.hlo.txt"), "w") as f:
            f.write(to_hlo_text(lowered))
        entries[name] = {"inputs": _sig(q_in), "kind": "quantized", "width": w}
    return entries


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--datasets", nargs="*", default=list(datagen.SPECS))
    ap.add_argument("--models", nargs="*", default=MODELS)
    ap.add_argument("--widths", nargs="*", type=int, default=WIDTHS)
    ap.add_argument("--epochs", type=int, default=150)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"datasets": {}, "artifacts": {}, "widths": args.widths}
    t0 = time.time()
    for ds_name in args.datasets:
        spec = datagen.SPECS[ds_name]
        data_path = os.path.join(args.out_dir, f"data_{ds_name}.nbt")
        if os.path.exists(data_path):
            data = read_nbt(data_path)
            print(f"[{time.time()-t0:6.1f}s] {ds_name}: reusing {data_path}")
        else:
            data = datagen.generate(spec, seed=args.seed)
            q, qmin, qmax = ref.quantize(data["feat"])
            data["featq"] = q
            data["qrange"] = np.array([qmin, qmax], dtype=np.float32)
            write_nbt(data_path, data)
            print(
                f"[{time.time()-t0:6.1f}s] {ds_name}: generated "
                f"n={spec.n} nnz={int(data['meta'][1])}"
            )
        manifest["datasets"][ds_name] = {
            "n": int(data["meta"][0]),
            "nnz": int(data["meta"][1]),
            "feats": int(data["meta"][2]),
            "classes": int(data["meta"][3]),
            "scale": spec.scale,
            "paper_nodes": spec.paper_nodes,
            "paper_avg_deg": spec.paper_avg_deg,
            "ideal_acc": {},
        }

        for model_name in args.models:
            wpath = os.path.join(args.out_dir, f"weights_{model_name}_{ds_name}.nbt")
            if os.path.exists(wpath):
                stored = read_nbt(wpath)
                params = {k: v for k, v in stored.items() if k != "ideal_acc"}
                acc = float(stored["ideal_acc"][0])
                print(f"[{time.time()-t0:6.1f}s]   {model_name}: reusing weights (acc={acc:.4f})")
            else:
                params, acc = T.train(
                    model_name, data, epochs=args.epochs, seed=args.seed
                )
                stored = dict(params)
                stored["ideal_acc"] = np.array([acc], dtype=np.float32)
                write_nbt(wpath, stored)
                print(f"[{time.time()-t0:6.1f}s]   {model_name}: trained, test acc={acc:.4f}")
            manifest["datasets"][ds_name]["ideal_acc"][model_name] = acc

            entries = lower_artifacts_for(
                model_name, ds_name, data, args.out_dir, widths=args.widths
            )
            manifest["artifacts"].update(entries)
            print(f"[{time.time()-t0:6.1f}s]   {model_name}: lowered {len(entries)} artifacts")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"[{time.time()-t0:6.1f}s] manifest written — done")


if __name__ == "__main__":
    main()
