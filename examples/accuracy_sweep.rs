//! Accuracy-vs-width sweep (a miniature Fig. 6): for one model/dataset,
//! print accuracy for every strategy at every compiled W, next to the
//! exact ideal and the sampling rate.
//!
//! ```bash
//! cargo run --release --example accuracy_sweep -- [model] [dataset]
//! ```

use anyhow::Result;

use aes_spmm::quant::Precision;
use aes_spmm::runtime::{accuracy, run_forward, Dataset, Engine, ForwardRequest, Weights};
use aes_spmm::sampling::{sampling_rate, Strategy};

fn main() -> Result<()> {
    let model = std::env::args().nth(1).unwrap_or_else(|| "gcn".into());
    let dataset = std::env::args().nth(2).unwrap_or_else(|| "proteins".into());
    let artifacts = "artifacts";

    let engine = Engine::new(artifacts)?;
    let ds = Dataset::load(artifacts, &dataset)?;
    let weights = Weights::load(artifacts, &model, &dataset)?;
    println!(
        "{model} on {dataset}: ideal accuracy {:.4} (exact aggregation)",
        weights.ideal_acc
    );
    println!(
        "\n{:>6} {:>10} {:>10} {:>10} {:>10} {:>12}",
        "W", "afs", "sfs", "aes", "aes+int8", "aes rate"
    );

    for &w in &engine.manifest().widths.clone() {
        let mut cells = Vec::new();
        for (strategy, precision) in [
            (Strategy::Afs, Precision::F32),
            (Strategy::Sfs, Precision::F32),
            (Strategy::Aes, Precision::F32),
            (Strategy::Aes, Precision::U8Device),
        ] {
            let r = run_forward(
                &engine,
                &ds,
                &weights,
                &ForwardRequest {
                    model: model.clone(),
                    dataset: dataset.clone(),
                    width: Some(w),
                    strategy,
                    precision,
                },
                None,
            )?;
            cells.push(accuracy(&ds, &r.logits)?);
        }
        let rate = sampling_rate(&ds.csr_gcn, w, Strategy::Aes);
        println!(
            "{:>6} {:>10.4} {:>10.4} {:>10.4} {:>10.4} {:>11.1}%",
            w, cells[0], cells[1], cells[2], cells[3], rate * 100.0
        );
    }
    Ok(())
}
