//! Quickstart: load a dataset + trained GCN, run one sampled inference
//! through the AOT PJRT artifact, and compare against the exact baseline.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use anyhow::Result;

use aes_spmm::quant::Precision;
use aes_spmm::runtime::{accuracy, run_forward, Dataset, Engine, ForwardRequest, Weights};
use aes_spmm::sampling::{sampling_rate, Strategy};

fn main() -> Result<()> {
    let artifacts = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let engine = Engine::new(&artifacts)?;
    println!("PJRT platform: {}", engine.platform());

    let ds = Dataset::load(&artifacts, "cora")?;
    let weights = Weights::load(&artifacts, "gcn", "cora")?;
    println!(
        "dataset cora: {} nodes, {} edges, {} features, {} classes",
        ds.n, ds.nnz, ds.feats, ds.classes
    );

    // Exact forward (the cuSPARSE-role baseline artifact).
    let exact = run_forward(
        &engine,
        &ds,
        &weights,
        &ForwardRequest {
            model: "gcn".into(),
            dataset: "cora".into(),
            width: None,
            strategy: Strategy::Aes,
            precision: Precision::F32,
        },
        None,
    )?;
    println!(
        "exact    : acc {:.4}  exec {:?}",
        accuracy(&ds, &exact.logits)?,
        exact.stats.execute + exact.stats.fetch
    );

    // AES-sampled forward at W=32: the paper's kernel, fused into the
    // same compiled module (sample → SpMM → MLP).
    for (strategy, width) in [(Strategy::Aes, 32), (Strategy::Afs, 32), (Strategy::Sfs, 32)] {
        let rate = sampling_rate(&ds.csr_gcn, width, strategy);
        let r = run_forward(
            &engine,
            &ds,
            &weights,
            &ForwardRequest {
                model: "gcn".into(),
                dataset: "cora".into(),
                width: Some(width),
                strategy,
                precision: Precision::F32,
            },
            None,
        )?;
        println!(
            "{} w{width}: acc {:.4}  exec {:?}  (sampling rate {:.1}%)",
            strategy.name(),
            accuracy(&ds, &r.logits)?,
            r.stats.execute + r.stats.fetch,
            rate * 100.0
        );
    }

    // Quantized path: INT8 features + on-device dequantization.
    let q = run_forward(
        &engine,
        &ds,
        &weights,
        &ForwardRequest {
            model: "gcn".into(),
            dataset: "cora".into(),
            width: Some(32),
            strategy: Strategy::Aes,
            precision: Precision::U8Device,
        },
        None,
    )?;
    println!(
        "aes w32 + int8 features: acc {:.4}  (features {}x smaller on the wire)",
        accuracy(&ds, &q.logits)?,
        ds.feat.byte_len() / ds.featq.byte_len()
    );
    Ok(())
}
