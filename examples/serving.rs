//! End-to-end serving driver (the repo's required E2E validation): start
//! the coordinator over every dataset/model, replay a mixed request
//! stream against it, and report latency/throughput/batching metrics.
//!
//! ```bash
//! make artifacts && cargo run --release --example serving [-- <requests>]
//! ```

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use aes_spmm::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, ModelStore, RouteKey, SubmitError,
};
use aes_spmm::quant::Precision;
use aes_spmm::rng::Pcg32;
use aes_spmm::runtime::Engine;
use aes_spmm::sampling::Strategy;

fn main() -> Result<()> {
    let n_requests: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(300);
    let artifacts = "artifacts";

    let engine = Arc::new(Engine::new(artifacts)?);
    // Serve the small datasets (low-latency tier) plus one large graph.
    let datasets: Vec<String> =
        ["cora", "pubmed", "arxiv", "proteins"].iter().map(|s| s.to_string()).collect();
    let models = vec!["gcn".to_string(), "sage".to_string()];
    let store = Arc::new(ModelStore::load(artifacts, &datasets, &models)?);

    let coord = Coordinator::start(
        engine.clone(),
        store.clone(),
        CoordinatorConfig {
            workers: 3,
            queue_depth: 512,
            batcher: BatcherConfig { max_batch: 32, max_delay: Duration::from_millis(2) },
            ..CoordinatorConfig::default()
        },
    );

    // Warm the executable cache so steady-state latency is measured: one
    // compile per (model, dataset, width, precision) — strategies share
    // the compiled artifact (runtime scalar input).
    println!("warming artifact cache (12 artifacts)...");
    let widths = [64usize];
    for ds in &datasets {
        for m in &models {
            for &w in &widths {
                for precision in [Precision::F32, Precision::U8Device] {
                    let key = RouteKey {
                        model: m.clone(),
                        dataset: ds.clone(),
                        width: Some(w),
                        strategy: Strategy::Aes,
                        precision,
                    };
                    coord.infer(key, vec![0])?;
                }
            }
        }
    }

    println!("replaying {n_requests} mixed requests...");
    let mut rng = Pcg32::new(99);
    let t0 = Instant::now();
    let mut pending = Vec::new();
    let mut busy_retries = 0usize;
    for _ in 0..n_requests {
        let ds = datasets[rng.usize_below(datasets.len())].clone();
        let n = store.dataset(&ds)?.n;
        let key = RouteKey {
            model: models[rng.usize_below(models.len())].clone(),
            dataset: ds,
            width: Some(widths[rng.usize_below(widths.len())]),
            strategy: [Strategy::Afs, Strategy::Sfs, Strategy::Aes][rng.usize_below(3)],
            precision: if rng.f32() < 0.5 { Precision::U8Device } else { Precision::F32 },
        };
        let nodes: Vec<usize> = (0..4).map(|_| rng.usize_below(n)).collect();
        loop {
            match coord.submit(key.clone(), nodes.clone()) {
                Ok((_, rx)) => {
                    pending.push(rx);
                    break;
                }
                Err(SubmitError::Busy) => {
                    busy_retries += 1;
                    std::thread::sleep(Duration::from_micros(500));
                }
                Err(e) => anyhow::bail!("submit: {e}"),
            }
        }
    }

    let mut ok = 0usize;
    for rx in pending {
        let resp = rx.recv()?;
        match resp.error {
            None => ok += 1,
            Some(e) => eprintln!("request {} failed: {e}", resp.id),
        }
    }
    let wall = t0.elapsed();
    let snap = coord.metrics().snapshot();
    println!("\n== serving results ==");
    println!("requests: {ok}/{n_requests} ok, {} rejected transiently", busy_retries);
    println!(
        "wall {:?} | throughput {:.1} req/s | {} forward passes (amortization {:.1} req/exec)",
        wall,
        ok as f64 / wall.as_secs_f64(),
        snap.batches,
        coord.metrics().amortization(),
    );
    println!(
        "latency p50 {:?} p99 {:?} mean {:?}",
        snap.latency_p50, snap.latency_p99, snap.latency_mean
    );
    println!(
        "stage p50: queue {:?} | feature load {:?} | execute {:?}",
        snap.queue_wait_p50, snap.load_p50, snap.exec_p50
    );
    println!(
        "plan cache: {} warm hits / {} cold builds ({} routes resident)",
        snap.plan_hits,
        snap.plan_misses,
        coord.plan_cache_len()
    );
    println!("\ntop routes:");
    let mut routes: Vec<_> = snap.per_route.iter().collect();
    routes.sort_by_key(|(_, c)| std::cmp::Reverse(**c));
    for (route, count) in routes.iter().take(10) {
        println!("  {route}: {count} executions");
    }
    coord.shutdown();
    Ok(())
}
