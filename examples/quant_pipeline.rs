//! Quantized feature pipeline demo (Table 3's mechanism): compare the
//! fp32 and INT8 loading paths end to end — bytes moved, load time,
//! host-vs-device dequantization, and the resulting accuracy delta.
//!
//! ```bash
//! cargo run --release --example quant_pipeline -- [dataset]
//! ```

use anyhow::Result;

use aes_spmm::quant::{FeatureStore, Features, Precision};
use aes_spmm::runtime::{accuracy, run_forward, Dataset, Engine, ForwardRequest, Weights};
use aes_spmm::sampling::Strategy;
use aes_spmm::util::fmt_duration;

fn main() -> Result<()> {
    let dataset = std::env::args().nth(1).unwrap_or_else(|| "products".into());
    let artifacts = "artifacts";
    let engine = Engine::new(artifacts)?;
    let ds = Dataset::load(artifacts, &dataset)?;
    let weights = Weights::load(artifacts, "gcn", &dataset)?;
    let fstore = FeatureStore::open(format!("{artifacts}/data_{dataset}.nbt"))?;

    println!("dataset {dataset}: {} nodes x {} features", ds.n, ds.feats);
    println!(
        "quant range: [{:.3}, {:.3}], max reconstruction error {:.5}\n",
        ds.qparams.x_min,
        ds.qparams.x_max,
        aes_spmm::quant::max_quant_error(ds.qparams)
    );

    let width = 64;
    println!(
        "{:<12} {:>12} {:>12} {:>12} {:>10}",
        "path", "bytes", "load", "dequant", "accuracy"
    );
    for precision in [Precision::F32, Precision::U8Host, Precision::U8Device] {
        // Load via the instrumented store (the per-inference path).
        let (feats, stats) = fstore.load(precision)?;
        let feat_tensor = match feats {
            Features::Dense(t) => t,
            Features::Quantized { q, .. } => q,
            // load() is the eager path; only stage() streams.
            Features::Streamed(h) => h.to_dense(),
        };
        let r = run_forward(
            &engine,
            &ds,
            &weights,
            &ForwardRequest {
                model: "gcn".into(),
                dataset: dataset.clone(),
                width: Some(width),
                strategy: Strategy::Aes,
                precision,
            },
            Some(&feat_tensor),
        )?;
        println!(
            "{:<12} {:>12} {:>12} {:>12} {:>10.4}",
            precision.name(),
            stats.bytes_read,
            fmt_duration(stats.read_time),
            if stats.dequant_time.is_zero() {
                "on-device".to_string()
            } else {
                fmt_duration(stats.dequant_time)
            },
            accuracy(&ds, &r.logits)?,
        );
    }
    println!(
        "\nINT8 moves 4x fewer bytes; dequantization runs either on the host\n\
         (u8-host row, CPU baselines) or inside the compiled artifact as the\n\
         Pallas dequant kernel (u8-device row, the paper's design)."
    );
    Ok(())
}
