//! Vendored stand-in for the `anyhow` crate — the offline registry carries
//! no external crates, so this ships the API subset the workspace uses:
//! [`Error`], [`Result`], the [`Context`] extension trait (on both
//! `Result` and `Option`), and the `anyhow!` / `bail!` macros.
//!
//! Semantics match upstream where it matters:
//! * `Display` prints the outermost message; `{:#}` prints the full
//!   `outer: inner: ...` context chain.
//! * `Debug` (what `.unwrap()` shows) prints the message plus a
//!   `Caused by:` list.
//! * Any `std::error::Error + Send + Sync + 'static` converts via `?`.

use std::error::Error as StdError;
use std::fmt;

/// `Result<T, anyhow::Error>` with the same default-parameter shape as
/// upstream (so `Result<T, SomeOtherError>` through this alias still works).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A dynamic error: an outermost message plus the chain of causes it wraps.
pub struct Error {
    msg: String,
    /// Causes, outermost wrapped error first.
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), chain: Vec::new() }
    }

    fn from_std<E: StdError + ?Sized>(err: &E) -> Error {
        let msg = err.to_string();
        let mut chain = Vec::new();
        let mut src = err.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { msg, chain }
    }

    /// Wrap this error in a new outermost context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        let mut chain = Vec::with_capacity(self.chain.len() + 1);
        chain.push(self.msg);
        chain.extend(self.chain);
        Error { msg: context.to_string(), chain }
    }

    /// The message chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        std::iter::once(self.msg.as_str()).chain(self.chain.iter().map(|s| s.as_str()))
    }

    /// The innermost (root) cause message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or(&self.msg)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if f.alternate() {
            for cause in &self.chain {
                write!(f, ": {cause}")?;
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if !self.chain.is_empty() {
            f.write_str("\n\nCaused by:")?;
            for cause in &self.chain {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`; that
// is what keeps this blanket `From` coherent (same trick as upstream).
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Error {
        Error::from_std(&err)
    }
}

mod ext {
    use super::{Error, StdError};

    /// Anything that can become an [`Error`] — std errors and `Error`
    /// itself. Mirrors upstream's private `ext::StdError` shim so that
    /// `Context` works uniformly on `Result<T, E>` and `Result<T, Error>`.
    pub trait IntoError {
        fn into_error(self) -> Error;
    }

    impl<E: StdError + Send + Sync + 'static> IntoError for E {
        fn into_error(self) -> Error {
            Error::from_std(&self)
        }
    }

    impl IntoError for Error {
        fn into_error(self) -> Error {
            self
        }
    }
}

/// Attach context to errors (`Result`) or turn `None` into an error.
pub trait Context<T, E>: Sized {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error>;
}

impl<T, E: ext::IntoError> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn from_std_and_context_chain() {
        let e: Error = io_err().into();
        let e = e.context("loading dataset");
        assert_eq!(format!("{e}"), "loading dataset");
        assert_eq!(format!("{e:#}"), "loading dataset: missing file");
        assert_eq!(e.root_cause(), "missing file");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: missing file");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(format!("{e}"), "missing key");
        assert_eq!(Some(7).context("nope").unwrap(), 7);
    }

    #[test]
    fn context_on_anyhow_result() {
        let r: Result<()> = Err(anyhow!("inner {}", 42));
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner 42");
        assert_eq!(e.chain().collect::<Vec<_>>(), vec!["outer", "inner 42"]);
    }

    #[test]
    fn bail_macro() {
        fn f(fail: bool) -> Result<u32> {
            if fail {
                bail!("broke with code {}", 3);
            }
            Ok(1)
        }
        assert_eq!(f(false).unwrap(), 1);
        assert_eq!(format!("{}", f(true).unwrap_err()), "broke with code 3");
    }

    #[test]
    fn debug_shows_causes() {
        let e: Error = io_err().into();
        let e = e.context("ctx");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("ctx"));
        assert!(dbg.contains("Caused by:"));
        assert!(dbg.contains("missing file"));
    }

    #[test]
    fn question_mark_converts() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(format!("{}", f().unwrap_err()), "missing file");
    }
}
