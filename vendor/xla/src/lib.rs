//! Offline stub of the `xla` crate's PJRT surface.
//!
//! The offline registry has no XLA runtime, so this crate keeps the
//! workspace compiling and the non-PJRT 95% of the system testable:
//!
//! * [`Literal`] is fully functional host-side (shape + typed payload) —
//!   `Tensor::to_literal` and round-trips work without any runtime.
//! * Every device-touching operation ([`PjRtClient::cpu`] first of all)
//!   returns a clear [`Error`] instead of executing, so callers fail fast
//!   with "stub" in the message rather than crashing.
//!
//! Swap the `xla` path dependency in `rust/Cargo.toml` for the real
//! bindings to execute the AOT artifacts; the API here is signature-
//! compatible with the subset the workspace calls.

use std::fmt;

/// Stub error: carries the operation that needed the real runtime.
#[derive(Clone, Debug)]
pub struct Error {
    msg: String,
}

impl Error {
    fn unavailable(op: &str) -> Error {
        Error {
            msg: format!(
                "{op}: XLA/PJRT runtime not available (offline `xla` stub; \
                 link the real bindings to execute compiled artifacts)"
            ),
        }
    }

    fn msg(msg: impl Into<String>) -> Error {
        Error { msg: msg.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// XLA element types (subset + padding variants so wildcard matches stay
/// reachable).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ElementType {
    Pred,
    S8,
    S16,
    S32,
    S64,
    U8,
    U16,
    U32,
    U64,
    F16,
    Bf16,
    F32,
    F64,
    C64,
    C128,
}

/// Array shape of a literal: element type + dimensions.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ArrayShape {
    ty: ElementType,
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn ty(&self) -> ElementType {
        self.ty
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Sealed decoding support for [`Literal::to_vec`].
pub trait NativeType: Sized {
    const TY: ElementType;
    const SIZE: usize;
    fn from_le(bytes: &[u8]) -> Self;
}

macro_rules! native {
    ($ty:ty, $elem:expr, $size:expr) => {
        impl NativeType for $ty {
            const TY: ElementType = $elem;
            const SIZE: usize = $size;
            fn from_le(bytes: &[u8]) -> Self {
                <$ty>::from_le_bytes(bytes.try_into().expect("slice length checked by caller"))
            }
        }
    };
}

native!(f32, ElementType::F32, 4);
native!(f64, ElementType::F64, 8);
native!(i32, ElementType::S32, 4);
native!(i64, ElementType::S64, 8);
native!(u8, ElementType::U8, 1);

/// A host literal: element type, dims, little-endian payload.
#[derive(Clone, Debug)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<i64>,
    data: Vec<u8>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        Ok(Literal {
            ty,
            dims: dims.iter().map(|&d| d as i64).collect(),
            data: data.to_vec(),
        })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape { ty: self.ty, dims: self.dims.clone() })
    }

    /// Unwrap a 1-tuple literal. Tuples only come back from device
    /// execution, which the stub cannot perform.
    pub fn to_tuple1(self) -> Result<Literal> {
        Err(Error::unavailable("Literal::to_tuple1"))
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.ty != T::TY {
            return Err(Error::msg(format!(
                "literal element type {:?} != requested {:?}",
                self.ty,
                T::TY
            )));
        }
        if self.data.len() % T::SIZE != 0 {
            return Err(Error::msg("literal payload not a multiple of the element size"));
        }
        Ok(self.data.chunks_exact(T::SIZE).map(T::from_le).collect())
    }
}

/// Stub device buffer — never constructible through a real transfer.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Stub compiled executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

/// Stub PJRT client. [`PjRtClient::cpu`] fails fast so callers surface a
/// single clear error at engine construction instead of deep in a batch.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _literal: &Literal,
    ) -> Result<PjRtBuffer> {
        Err(Error::unavailable("PjRtClient::buffer_from_host_literal"))
    }
}

/// Stub HLO module proto (text parsing needs the real runtime).
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::unavailable("HloModuleProto::from_text_file"))
    }
}

/// Stub XLA computation wrapper.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let vals = [1.0f32, -2.5, 3.25];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &bytes).unwrap();
        let shape = lit.array_shape().unwrap();
        assert_eq!(shape.ty(), ElementType::F32);
        assert_eq!(shape.dims(), &[3]);
        assert_eq!(lit.to_vec::<f32>().unwrap(), vals);
        assert!(lit.to_vec::<i32>().is_err(), "wrong-type view must fail");
    }

    #[test]
    fn device_ops_error_clearly() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("stub"));
        assert!(PjRtBuffer.to_literal_sync().is_err());
        assert!(PjRtLoadedExecutable.execute_b(&[]).is_err());
        assert!(HloModuleProto::from_text_file("x").is_err());
    }
}
