//! `acc_diff` — CI's accuracy-regression gate, the `bench_diff` twin
//! for `ACC_eval.json`.
//!
//! ```text
//! acc_diff <fresh.json> <baseline.json> [--tolerance 0.005]
//! ```
//!
//! Two layers of gating:
//!
//! 1. **Budget violations always fail**, baseline or not: any entry in
//!    the fresh report (a grid config or a cross-config check — any
//!    JSON object carrying `name` + `pass`) with `pass: false` means
//!    the serving stack broke the paper's accuracy claims outright.
//! 2. **Regression vs the committed baseline**: for every baseline
//!    config (entries that also carry `top1_agreement`), the fresh
//!    agreement may not drop by more than `--tolerance`; a baseline
//!    entry missing from the fresh run fails (silent coverage loss —
//!    a renamed config or a crashed grid must force a deliberate
//!    baseline refresh). Fresh-only entries are informational.
//!
//! The conformance grid is bit-deterministic (seeded data, deterministic
//! sampling, FP order pinned by the oracle contract), so agreements are
//! exactly reproducible across machines; the default tolerance only
//! absorbs deliberate small budget-neutral changes between refreshes.
//!
//! A missing baseline file is the bootstrap state: the tool prints how
//! to seed `benchmarks/baseline/ACC_eval.json` and exits 0 — unless the
//! fresh run itself has failures. Exit codes: 0 = pass (or bootstrap),
//! 1 = violation/regression, 2 = usage or malformed input.

use std::collections::BTreeMap;
use std::process::ExitCode;

use aes_spmm::util::{
    cli_flag_f64, cli_positionals, cli_require_known_flags, parse_json, JsonValue,
};

/// One gated entry of a report: a grid config (`top1` present) or a
/// cross-config check (`top1` absent).
#[derive(Clone, Copy, Debug, PartialEq)]
struct Entry {
    top1: Option<f64>,
    pass: bool,
}

/// Recursively collect `(name, Entry)` from any object carrying `name`
/// + `pass` (schema-agnostic, like bench_diff's case discovery).
fn collect_entries(v: &JsonValue, out: &mut BTreeMap<String, Entry>) {
    match v {
        JsonValue::Obj(map) => {
            let name = map.get("name").and_then(|n| n.as_str().ok());
            let pass = map.get("pass").and_then(|p| match p {
                JsonValue::Bool(b) => Some(*b),
                _ => None,
            });
            if let (Some(name), Some(pass)) = (name, pass) {
                let top1 = match map.get("top1_agreement") {
                    Some(JsonValue::Num(x)) => Some(*x),
                    _ => None,
                };
                out.insert(name.to_string(), Entry { top1, pass });
                return;
            }
            for val in map.values() {
                collect_entries(val, out);
            }
        }
        JsonValue::Arr(items) => {
            for item in items {
                collect_entries(item, out);
            }
        }
        _ => {}
    }
}

fn load_entries(path: &str) -> Result<BTreeMap<String, Entry>, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("cannot read {path}: {e}"))?;
    let doc = parse_json(&text).map_err(|e| format!("{path} is not valid JSON: {e}"))?;
    let mut entries = BTreeMap::new();
    collect_entries(&doc, &mut entries);
    if entries.is_empty() {
        return Err(format!("{path} holds no entries (objects with name + pass)"));
    }
    Ok(entries)
}

fn run() -> Result<bool, String> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    cli_require_known_flags(&args, &["--tolerance"])?;
    let positional = cli_positionals(&args);
    let [fresh_path, baseline_path] = positional.as_slice() else {
        return Err(
            "usage: acc_diff <fresh.json> <baseline.json> [--tolerance 0.005]".to_string()
        );
    };
    let tolerance = cli_flag_f64(&args, "--tolerance", 0.005)?;

    let fresh = load_entries(fresh_path)?;
    let mut failures = 0usize;
    for (name, e) in &fresh {
        if !e.pass {
            println!("  [FAIL]  {name} (accuracy budget violated in the fresh run)");
            failures += 1;
        }
    }

    if !std::path::Path::new(baseline_path.as_str()).exists() {
        println!("acc_diff: no baseline at {baseline_path} — bootstrap run.");
        println!(
            "  {} fresh entr(ies) measured; to arm the regression gate, commit the fresh file:",
            fresh.len()
        );
        println!("    cp {fresh_path} {baseline_path}");
        if failures > 0 {
            println!("acc_diff: {failures} budget violation(s) — failing despite bootstrap.");
        }
        return Ok(failures == 0);
    }

    let baseline = load_entries(baseline_path)?;
    let mut gone = 0usize;
    let mut drops = 0usize;
    let mut compared = 0usize;
    for (name, base) in &baseline {
        let Some(new) = fresh.get(name) else {
            println!("  [GONE]  {name} (in baseline, not in fresh run)");
            gone += 1;
            continue;
        };
        let (Some(b), Some(n)) = (base.top1, new.top1) else { continue };
        compared += 1;
        let drop = b - n;
        if drop > tolerance {
            println!("  [DROP]  {name}: top-1 agreement {b:.4} -> {n:.4} (-{drop:.4})");
            drops += 1;
        }
    }
    for name in fresh.keys() {
        if !baseline.contains_key(name) {
            println!("  [new]   {name} (no baseline yet)");
        }
    }
    println!(
        "acc_diff: {compared} config(s) compared, {failures} budget violation(s), \
         {drops} drop(s) beyond {tolerance}, {gone} baseline entr(ies) missing from the fresh run"
    );
    Ok(failures == 0 && drops == 0 && gone == 0)
}

fn main() -> ExitCode {
    match run() {
        Ok(true) => ExitCode::SUCCESS,
        Ok(false) => ExitCode::FAILURE,
        Err(msg) => {
            eprintln!("acc_diff: {msg}");
            ExitCode::from(2)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entries_of(text: &str) -> BTreeMap<String, Entry> {
        let mut out = BTreeMap::new();
        collect_entries(&parse_json(text).unwrap(), &mut out);
        out
    }

    #[test]
    fn collects_configs_and_checks() {
        // The ACC_eval.json shape: configs carry top1, checks do not.
        let doc = r#"{"report":"acc_eval","pass":true,
            "configs":[
                {"name":"d/exact/f32/shards1","top1_agreement":1.0,"pass":true},
                {"name":"d/aes-w8/u8-streamed/shards3","top1_agreement":0.9938,"pass":true}],
            "checks":[{"name":"sharded == unsharded (d/exact/f32)","pass":true,"detail":"ok"}]}"#;
        let e = entries_of(doc);
        assert_eq!(e.len(), 3);
        assert_eq!(e["d/exact/f32/shards1"].top1, Some(1.0));
        assert!(e["sharded == unsharded (d/exact/f32)"].top1.is_none());
        assert!(e.values().all(|x| x.pass));
    }

    #[test]
    fn entry_objects_do_not_recurse_into_themselves() {
        let doc = r#"[{"name":"x","pass":true,"extra":{"name":"inner","pass":false}}]"#;
        let e = entries_of(doc);
        assert_eq!(e.len(), 1);
        assert!(e["x"].pass);
    }

    #[test]
    fn top_level_pass_flag_is_not_an_entry() {
        // The root object has "pass" but no "name": recursion continues
        // into it rather than swallowing the document.
        let doc = r#"{"pass":false,"configs":[{"name":"a","top1_agreement":0.5,"pass":false}]}"#;
        let e = entries_of(doc);
        assert_eq!(e.len(), 1);
        assert!(!e["a"].pass);
    }

    #[test]
    fn drop_math_matches_the_gate() {
        // tolerance 0.005: a 0.004 drop passes, a 0.006 drop fails.
        let base = 0.993f64;
        for (new, fails) in [(0.989, false), (0.987, true)] {
            let drop: f64 = base - new;
            assert_eq!(drop > 0.005, fails, "drop {drop}");
        }
    }
}
