#!/usr/bin/env python3
"""Generate the golden accuracy fixtures under rust/tests/fixtures/.

Produces, for each of two tiny hand-built graphs (a skewed 8-node graph
and a uniform 6-node ring):

  data_<name>.nbt         a complete dataset container (Dataset::load keys)
  weights_gcn_<name>.nbt  GCN weights in GCN_PARAM_ORDER (+ ideal_acc)
  oracle_<name>.nbt       the expected oracle logits ("logits", f32 [n, c])

The logits are computed here with *bit-exact f32 emulation* of
`eval::oracle_forward`'s canonical reduction order: every multiply and
add is rounded to f32 immediately. Computing each op in float64 and
then rounding to f32 yields the correctly-rounded f32 op by the
double-rounding theorem: binary64's 53 significand bits exceed the
2*24+2 = 50 bits that make double rounding innocuous for binary32
add/mul (the f32 *product* is even exact in f64; the exact *sum* of two
f32s generally is not — e.g. 1e30f32 + 1.0f32 — but the theorem covers
it). This argument is specific to binary32-via-binary64 add/mul; do NOT
extend the emulation to an f64 oracle or to fused ops on the same
reasoning. The Rust oracle must reproduce these bytes exactly — see
rust/tests/oracle_regression.rs and rust/tests/fixtures/README.md.

All graph values, features, and weights are dyadic rationals, keeping
every intermediate exactly representable; the f32 emulation makes the
result independent of that choice, the dyadics just keep the files
human-auditable.

Deterministic: re-running this script must reproduce the committed
fixture bytes. Python 3 stdlib only.
"""

import struct
from pathlib import Path

FIXTURE_DIR = Path(__file__).resolve().parent.parent / "rust" / "tests" / "fixtures"

F32, I32, U8, I64 = 0, 1, 2, 3
SIZES = {F32: 4, I32: 4, U8: 1, I64: 8}
PACK = {F32: "<f", I32: "<i", U8: "<B", I64: "<q"}


def f32(x):
    """Round a python float to the nearest f32 (IEEE-754 binary32)."""
    return struct.unpack("<f", struct.pack("<f", x))[0]


def write_nbt(path, tensors):
    """tensors: list of (name, dtype, shape, flat_values)."""
    buf = bytearray(b"NBTC")
    buf += struct.pack("<I", len(tensors))
    for name, dtype, shape, values in tensors:
        n_elems = 1
        for d in shape:
            n_elems *= d
        assert len(values) == n_elems, f"{name}: {len(values)} values, shape {shape}"
        nb = name.encode()
        buf += struct.pack("<H", len(nb)) + nb
        buf += struct.pack("<I", dtype) + struct.pack("<I", len(shape))
        for d in shape:
            buf += struct.pack("<Q", d)
        payload = b"".join(struct.pack(PACK[dtype], v) for v in values)
        assert len(payload) == n_elems * SIZES[dtype]
        buf += struct.pack("<Q", len(payload)) + payload
    path.write_bytes(bytes(buf))
    print(f"wrote {path} ({len(buf)} bytes)")


# ---- the canonical oracle, f32-emulated -------------------------------

def matmul(a, b, m, k, n):
    out = [0.0] * (m * n)
    for i in range(m):
        for kk in range(k):
            av = a[i * k + kk]
            for j in range(n):
                out[i * n + j] = f32(out[i * n + j] + f32(av * b[kk * n + j]))
    return out


def aggregate(row_ptr, col_ind, val, b, n_rows, f):
    out = [0.0] * (n_rows * f)
    for i in range(n_rows):
        for e in range(row_ptr[i], row_ptr[i + 1]):
            v, col = val[e], col_ind[e]
            for j in range(f):
                out[i * f + j] = f32(out[i * f + j] + f32(v * b[col * f + j]))
    return out


def oracle_forward(graph, feat, w0, b0, w1, b1, n, f, h, c):
    row_ptr, col_ind, val = graph
    xw = matmul(feat, w0, n, f, h)
    hidden = aggregate(row_ptr, col_ind, val, xw, n, h)
    for i in range(n):
        for j in range(h):
            v = f32(hidden[i * h + j] + b0[j])
            hidden[i * h + j] = v if v > 0.0 else 0.0
    hw = matmul(hidden, w1, n, h, c)
    logits = aggregate(row_ptr, col_ind, val, hw, n, c)
    for i in range(n):
        for j in range(c):
            logits[i * c + j] = f32(logits[i * c + j] + b1[j])
    return logits


# ---- fixture construction ---------------------------------------------

def build_csr(n, rows):
    """rows: list of sorted column lists. Dyadic values 0.25/0.375/0.5."""
    row_ptr, col_ind, val = [0], [], []
    for i, cols in enumerate(rows):
        assert cols == sorted(cols) and all(0 <= c < n for c in cols)
        for c in cols:
            col_ind.append(c)
            val.append(0.25 + 0.125 * ((i + c) % 3))
        row_ptr.append(len(col_ind))
    return row_ptr, col_ind, val


def quantize(data, lo, hi):
    inv = f32(255.0 / f32(hi - lo))
    out = []
    for x in data:
        q = int(f32(f32(x - lo) * inv) // 1)  # floor
        out.append(max(0, min(255, q)))
    return out


def emit(name, rows, n, f, h, c):
    row_ptr, col_ind, val = build_csr(n, rows)
    nnz = len(col_ind)
    # Dyadic features/weights via small modular patterns (no randomness).
    feat = [((i * f + j) % 7) * 0.25 - 0.75 for i in range(n) for j in range(f)]
    w0 = [(((j * h + k) % 5) - 2) * 0.125 for j in range(f) for k in range(h)]
    b0 = [[0.0625, -0.125, 0.09375, 0.046875][k % 4] for k in range(h)]
    w1 = [(((j * c + k) % 7) - 3) * 0.0625 for j in range(h) for k in range(c)]
    b1 = [[0.03125, -0.0625, 0.015625][k % 3] for k in range(c)]
    labels = [i % c for i in range(n)]

    # Every input must be exactly f32-representable (dyadic by design).
    for v in feat + w0 + b0 + w1 + b1 + val:
        assert f32(v) == v, f"{name}: {v} is not exactly f32-representable"

    lo, hi = min(feat), max(feat)
    write_nbt(FIXTURE_DIR / f"data_{name}.nbt", [
        ("meta", I64, [4], [n, nnz, f, c]),
        ("row_ptr", I32, [n + 1], row_ptr),
        ("col_ind", I32, [nnz], col_ind),
        ("val_gcn", F32, [nnz], val),
        ("val_ones", F32, [nnz], [1.0] * nnz),
        ("feat", F32, [n, f], feat),
        ("featq", U8, [n, f], quantize(feat, lo, hi)),
        ("qrange", F32, [2], [lo, hi]),
        ("labels", I32, [n], labels),
        ("train_mask", U8, [n], [0] * n),
    ])
    write_nbt(FIXTURE_DIR / f"weights_gcn_{name}.nbt", [
        ("w0", F32, [f, h], w0),
        ("b0", F32, [h], b0),
        ("w1", F32, [h, c], w1),
        ("b1", F32, [c], b1),
        ("ideal_acc", F32, [1], [1.0]),
    ])
    logits = oracle_forward((row_ptr, col_ind, val), feat, w0, b0, w1, b1, n, f, h, c)
    # The stored logits must survive the f32 round-trip bit-for-bit.
    assert all(f32(x) == x for x in logits)
    write_nbt(FIXTURE_DIR / f"oracle_{name}.nbt", [
        ("logits", F32, [n, c], logits),
    ])


def main():
    FIXTURE_DIR.mkdir(parents=True, exist_ok=True)
    # goldskew: 8 nodes, a degree-6 hub plus sparse tail rows.
    emit(
        "goldskew",
        rows=[
            [0, 1, 2, 3, 5, 7],
            [0, 1],
            [2],
            [0, 3, 4],
            [4, 6],
            [0, 5],
            [6],
            [0, 7],
        ],
        n=8, f=4, h=3, c=3,
    )
    # golduni: 6-node ring with self-loops — uniform degree 3.
    emit(
        "golduni",
        rows=[sorted({(i - 1) % 6, i, (i + 1) % 6}) for i in range(6)],
        n=6, f=5, h=4, c=2,
    )


if __name__ == "__main__":
    main()
