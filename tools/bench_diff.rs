//! `bench_diff` — CI's bench-regression gate.
//!
//! Compares a freshly generated bench JSON (`./ci.sh --bench` writes
//! `BENCH_spmm.json` / `BENCH_loading.json`) against a committed
//! baseline and fails when any case's median slowed down by more than
//! the threshold (throughput regression = time increase).
//!
//! ```text
//! bench_diff <fresh.json> <baseline.json> [--threshold 0.15] [--min-median-us 100]
//! ```
//!
//! * Cases are discovered structurally: any JSON object carrying both
//!   `name` and `median_ns` is a case; objects carrying `name` +
//!   `cases` (the per-workload grouping) extend the case's path prefix.
//!   This makes the tool agnostic to the exact report schema, so both
//!   bench files — and future ones — diff without changes here.
//! * Cases whose **baseline** median is under `--min-median-us` are
//!   reported informationally but never fail the gate: micro-times
//!   jitter far beyond any sane threshold on shared CI runners.
//! * A baseline case missing from the fresh run **fails** the gate —
//!   silent coverage loss (a renamed bench, a bench that crashed after
//!   partial JSON) must force a deliberate baseline refresh. Fresh-only
//!   cases are informational.
//! * A missing baseline file is the bootstrap state (the repo starts
//!   with no toolchain-blessed numbers): the tool prints how to seed
//!   `benchmarks/baseline/` from the fresh file and exits 0.
//! * Exit codes: 0 = pass (or bootstrap), 1 = regression, 2 = usage or
//!   malformed input.

use std::collections::BTreeMap;
use std::process::ExitCode;

use aes_spmm::util::{
    cli_flag_f64, cli_positionals, cli_require_known_flags, parse_json, JsonValue,
};

/// Recursively collect `(path-qualified name, median_ns)` cases.
fn collect_cases(prefix: &str, v: &JsonValue, out: &mut BTreeMap<String, f64>) {
    match v {
        JsonValue::Obj(map) => {
            let name = map.get("name").and_then(|n| n.as_str().ok());
            if let (Some(name), Some(JsonValue::Num(median))) = (name, map.get("median_ns")) {
                let key = if prefix.is_empty() {
                    name.to_string()
                } else {
                    format!("{prefix} / {name}")
                };
                out.insert(key, *median);
                return;
            }
            // Grouping object: a name plus nested cases extends the path.
            let nested = match name {
                Some(n) if map.contains_key("cases") => {
                    if prefix.is_empty() {
                        n.to_string()
                    } else {
                        format!("{prefix} / {n}")
                    }
                }
                _ => prefix.to_string(),
            };
            for val in map.values() {
                collect_cases(&nested, val, out);
            }
        }
        JsonValue::Arr(items) => {
            for item in items {
                collect_cases(prefix, item, out);
            }
        }
        _ => {}
    }
}

fn load_cases(path: &str) -> Result<BTreeMap<String, f64>, String> {
    let text =
        std::fs::read_to_string(path).map_err(|e| format!("cannot read {path}: {e}"))?;
    let doc = parse_json(&text).map_err(|e| format!("{path} is not valid JSON: {e}"))?;
    let mut cases = BTreeMap::new();
    collect_cases("", &doc, &mut cases);
    if cases.is_empty() {
        return Err(format!("{path} holds no cases (objects with name + median_ns)"));
    }
    Ok(cases)
}

fn run() -> Result<bool, String> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    cli_require_known_flags(&args, &["--threshold", "--min-median-us"])?;
    let positional = cli_positionals(&args);
    let [fresh_path, baseline_path] = positional.as_slice() else {
        return Err("usage: bench_diff <fresh.json> <baseline.json> \
                    [--threshold 0.15] [--min-median-us 100]"
            .to_string());
    };
    let threshold = cli_flag_f64(&args, "--threshold", 0.15)?;
    let min_median_ns = cli_flag_f64(&args, "--min-median-us", 100.0)? * 1_000.0;

    let fresh = load_cases(fresh_path)?;
    if !std::path::Path::new(baseline_path.as_str()).exists() {
        println!("bench_diff: no baseline at {baseline_path} — bootstrap run.");
        println!(
            "  {} fresh case(s) measured; to arm the gate, commit the fresh file:",
            fresh.len()
        );
        println!("    cp {fresh_path} {baseline_path}");
        return Ok(true);
    }
    let baseline = load_cases(baseline_path)?;

    let mut regressions = Vec::new();
    let mut gone = Vec::new();
    let mut compared = 0usize;
    let mut noisy = 0usize;
    for (name, &base) in &baseline {
        let Some(&new) = fresh.get(name) else {
            // A vanished case fails the gate: a renamed bench or one
            // that crashed after partial JSON would otherwise shrink
            // coverage silently. Intentional renames go through a
            // baseline refresh (benchmarks/baseline/README.md).
            println!("  [GONE]  {name} (in baseline, not in fresh run)");
            gone.push(name.clone());
            continue;
        };
        compared += 1;
        let rel = new / base.max(1.0) - 1.0;
        if base < min_median_ns {
            noisy += 1;
            if rel > threshold {
                println!(
                    "  [noise] {name}: {:.0}ns -> {:.0}ns ({:+.1}%) — under the {}µs floor",
                    base,
                    new,
                    rel * 100.0,
                    min_median_ns / 1_000.0
                );
            }
            continue;
        }
        if rel > threshold {
            println!(
                "  [SLOW]  {name}: {:.2}ms -> {:.2}ms ({:+.1}%)",
                base / 1e6,
                new / 1e6,
                rel * 100.0
            );
            regressions.push(name.clone());
        }
    }
    for name in fresh.keys() {
        if !baseline.contains_key(name) {
            println!("  [new]   {name} (no baseline yet)");
        }
    }
    println!(
        "bench_diff: {compared} case(s) compared ({noisy} under the noise floor), \
         {} regression(s) beyond {:.0}%, {} baseline case(s) missing from the fresh run",
        regressions.len(),
        threshold * 100.0,
        gone.len()
    );
    Ok(regressions.is_empty() && gone.is_empty())
}

fn main() -> ExitCode {
    match run() {
        Ok(true) => ExitCode::SUCCESS,
        Ok(false) => ExitCode::FAILURE,
        Err(msg) => {
            eprintln!("bench_diff: {msg}");
            ExitCode::from(2)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cases_of(text: &str) -> BTreeMap<String, f64> {
        let mut out = BTreeMap::new();
        collect_cases("", &parse_json(text).unwrap(), &mut out);
        out
    }

    #[test]
    fn collects_flat_and_nested_cases() {
        // The spmm_kernels shape: workloads → named groups → cases.
        let spmm = r#"{"bench":"spmm_kernels","workloads":[
            {"name":"cora-like","n":2708,"cases":[
                {"name":"exact csr","median_ns":1000000,"iters":10},
                {"name":"sampled aes w16","median_ns":250000,"iters":10}]},
            {"name":"reddit-like","cases":[
                {"name":"exact csr","median_ns":9000000,"iters":5}]}]}"#;
        let c = cases_of(spmm);
        assert_eq!(c.len(), 3);
        assert_eq!(c["cora-like / exact csr"], 1e6);
        assert_eq!(c["reddit-like / exact csr"], 9e6);

        // The loading shape: top-level cases array.
        let loading = r#"{"bench":"loading","cases":[
            {"name":"cold stage fp32","median_ns":5000000,"bytes_staged":4096},
            {"name":"cold stage int8","median_ns":1200000,"bytes_staged":1024}]}"#;
        let c = cases_of(loading);
        assert_eq!(c.len(), 2);
        assert_eq!(c["cold stage int8"], 1.2e6);
    }

    #[test]
    fn case_objects_do_not_recurse_into_themselves() {
        // A case with extra nested junk is still exactly one case.
        let doc = r#"[{"name":"x","median_ns":5,"extra":{"name":"inner","median_ns":9}}]"#;
        assert_eq!(cases_of(doc).len(), 1);
    }

    // Flag/positional splitting is covered where the helpers live
    // (`util::cli`); both gate binaries share them.

    #[test]
    fn regression_math() {
        // 15% threshold: +14% passes, +16% fails (sanity on the formula
        // used in run(); kept in lockstep by construction).
        let base = 1_000_000.0f64;
        for (new, slow) in [(1_140_000.0, false), (1_160_000.0, true)] {
            let rel: f64 = new / base - 1.0;
            assert_eq!(rel > 0.15, slow);
        }
    }
}
