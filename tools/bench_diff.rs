//! `bench_diff` — CI's bench-regression gate.
//!
//! Compares a freshly generated bench JSON (`./ci.sh --bench` writes
//! `BENCH_spmm.json` / `BENCH_loading.json`; `./ci.sh --serve-only`
//! writes `BENCH_serving.json`) against a committed baseline and fails
//! when any case regressed by more than the threshold.
//!
//! ```text
//! bench_diff <fresh.json> <baseline.json> [--threshold 0.15] [--min-median-us 100]
//! ```
//!
//! * Cases are discovered structurally: any JSON object carrying `name`
//!   plus a metric — `median_ns` (a time) or `value` (a scalar) — is a
//!   case; objects carrying `name` + `cases` (the per-workload
//!   grouping) extend the case's path prefix. This makes the tool
//!   agnostic to the exact report schema, so all bench files — and
//!   future ones — diff without changes here.
//! * Each case has a **direction**: the optional `"direction"` field is
//!   `"lower"` (the `median_ns` default — times regress by going up) or
//!   `"higher"` (throughput regresses by going *down*). The baseline's
//!   direction governs the comparison, so a committed baseline defines
//!   its own gate semantics.
//! * Time cases (`median_ns`) whose **baseline** median is under
//!   `--min-median-us` are reported informationally but never fail the
//!   gate: micro-times jitter far beyond any sane threshold on shared
//!   CI runners. Scalar `value` cases have no such floor — their units
//!   are not times.
//! * A baseline case missing from the fresh run **fails** the gate —
//!   silent coverage loss (a renamed bench, a bench that crashed after
//!   partial JSON) must force a deliberate baseline refresh. Fresh-only
//!   cases are informational.
//! * A missing baseline file is the bootstrap state (the repo starts
//!   with no toolchain-blessed numbers): the tool prints how to seed
//!   `benchmarks/baseline/` from the fresh file and exits 0.
//! * Exit codes: 0 = pass (or bootstrap), 1 = regression, 2 = usage or
//!   malformed input.

use std::collections::BTreeMap;
use std::process::ExitCode;

use aes_spmm::util::{
    cli_flag_f64, cli_positionals, cli_require_known_flags, parse_json, JsonValue,
};

/// One discovered case: its metric, gate direction, and whether the
/// metric is a time (subject to the noise floor).
#[derive(Clone, Copy, Debug, PartialEq)]
struct Case {
    value: f64,
    higher_is_better: bool,
    time_like: bool,
}

/// Recursively collect path-qualified cases.
fn collect_cases(prefix: &str, v: &JsonValue, out: &mut BTreeMap<String, Case>) {
    match v {
        JsonValue::Obj(map) => {
            let name = map.get("name").and_then(|n| n.as_str().ok());
            let metric = match (map.get("median_ns"), map.get("value")) {
                (Some(JsonValue::Num(median)), _) => Some((*median, true)),
                (None, Some(JsonValue::Num(value))) => Some((*value, false)),
                _ => None,
            };
            if let (Some(name), Some((value, time_like))) = (name, metric) {
                let key = if prefix.is_empty() {
                    name.to_string()
                } else {
                    format!("{prefix} / {name}")
                };
                let higher_is_better = matches!(
                    map.get("direction").and_then(|d| d.as_str().ok()),
                    Some("higher")
                );
                out.insert(key, Case { value, higher_is_better, time_like });
                return;
            }
            // Grouping object: a name plus nested cases extends the path.
            let nested = match name {
                Some(n) if map.contains_key("cases") => {
                    if prefix.is_empty() {
                        n.to_string()
                    } else {
                        format!("{prefix} / {n}")
                    }
                }
                _ => prefix.to_string(),
            };
            for val in map.values() {
                collect_cases(&nested, val, out);
            }
        }
        JsonValue::Arr(items) => {
            for item in items {
                collect_cases(prefix, item, out);
            }
        }
        _ => {}
    }
}

fn load_cases(path: &str) -> Result<BTreeMap<String, Case>, String> {
    let text =
        std::fs::read_to_string(path).map_err(|e| format!("cannot read {path}: {e}"))?;
    let doc = parse_json(&text).map_err(|e| format!("{path} is not valid JSON: {e}"))?;
    let mut cases = BTreeMap::new();
    collect_cases("", &doc, &mut cases);
    if cases.is_empty() {
        return Err(format!("{path} holds no cases (objects with name + median_ns/value)"));
    }
    Ok(cases)
}

/// Format a case's metric for messages: times in ms, scalars raw.
fn fmt_metric(c: Case) -> String {
    if c.time_like {
        format!("{:.2}ms", c.value / 1e6)
    } else {
        format!("{:.2}", c.value)
    }
}

fn run() -> Result<bool, String> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    cli_require_known_flags(&args, &["--threshold", "--min-median-us"])?;
    let positional = cli_positionals(&args);
    let [fresh_path, baseline_path] = positional.as_slice() else {
        return Err("usage: bench_diff <fresh.json> <baseline.json> \
                    [--threshold 0.15] [--min-median-us 100]"
            .to_string());
    };
    let threshold = cli_flag_f64(&args, "--threshold", 0.15)?;
    let min_median_ns = cli_flag_f64(&args, "--min-median-us", 100.0)? * 1_000.0;

    let fresh = load_cases(fresh_path)?;
    if !std::path::Path::new(baseline_path.as_str()).exists() {
        println!("bench_diff: no baseline at {baseline_path} — bootstrap run.");
        println!(
            "  {} fresh case(s) measured; to arm the gate, commit the fresh file:",
            fresh.len()
        );
        println!("    cp {fresh_path} {baseline_path}");
        return Ok(true);
    }
    let baseline = load_cases(baseline_path)?;

    let mut regressions = Vec::new();
    let mut gone = Vec::new();
    let mut compared = 0usize;
    let mut noisy = 0usize;
    for (name, &base) in &baseline {
        let Some(&new) = fresh.get(name) else {
            // A vanished case fails the gate: a renamed bench or one
            // that crashed after partial JSON would otherwise shrink
            // coverage silently. Intentional renames go through a
            // baseline refresh (benchmarks/baseline/README.md).
            println!("  [GONE]  {name} (in baseline, not in fresh run)");
            gone.push(name.clone());
            continue;
        };
        compared += 1;
        // Regression drift, positive = worse, by the baseline's
        // direction: times get worse by growing, throughput by
        // shrinking.
        let drift = if base.higher_is_better {
            1.0 - new.value / base.value.max(1e-12)
        } else {
            new.value / base.value.max(1.0) - 1.0
        };
        if base.time_like && base.value < min_median_ns {
            noisy += 1;
            if drift > threshold {
                println!(
                    "  [noise] {name}: {:.0}ns -> {:.0}ns ({:+.1}%) — under the {}µs floor",
                    base.value,
                    new.value,
                    drift * 100.0,
                    min_median_ns / 1_000.0
                );
            }
            continue;
        }
        if drift > threshold {
            println!(
                "  [{}]  {name}: {} -> {} ({:.1}% worse)",
                if base.higher_is_better { "DROP" } else { "SLOW" },
                fmt_metric(base),
                fmt_metric(new),
                drift * 100.0
            );
            regressions.push(name.clone());
        }
    }
    for name in fresh.keys() {
        if !baseline.contains_key(name) {
            println!("  [new]   {name} (no baseline yet)");
        }
    }
    println!(
        "bench_diff: {compared} case(s) compared ({noisy} under the noise floor), \
         {} regression(s) beyond {:.0}%, {} baseline case(s) missing from the fresh run",
        regressions.len(),
        threshold * 100.0,
        gone.len()
    );
    Ok(regressions.is_empty() && gone.is_empty())
}

fn main() -> ExitCode {
    match run() {
        Ok(true) => ExitCode::SUCCESS,
        Ok(false) => ExitCode::FAILURE,
        Err(msg) => {
            eprintln!("bench_diff: {msg}");
            ExitCode::from(2)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cases_of(text: &str) -> BTreeMap<String, Case> {
        let mut out = BTreeMap::new();
        collect_cases("", &parse_json(text).unwrap(), &mut out);
        out
    }

    #[test]
    fn collects_flat_and_nested_cases() {
        // The spmm_kernels shape: workloads → named groups → cases.
        let spmm = r#"{"bench":"spmm_kernels","workloads":[
            {"name":"cora-like","n":2708,"cases":[
                {"name":"exact csr","median_ns":1000000,"iters":10},
                {"name":"sampled aes w16","median_ns":250000,"iters":10}]},
            {"name":"reddit-like","cases":[
                {"name":"exact csr","median_ns":9000000,"iters":5}]}]}"#;
        let c = cases_of(spmm);
        assert_eq!(c.len(), 3);
        assert_eq!(c["cora-like / exact csr"].value, 1e6);
        assert_eq!(c["reddit-like / exact csr"].value, 9e6);
        assert!(c.values().all(|v| v.time_like && !v.higher_is_better));

        // The loading shape: top-level cases array.
        let loading = r#"{"bench":"loading","cases":[
            {"name":"cold stage fp32","median_ns":5000000,"bytes_staged":4096},
            {"name":"cold stage int8","median_ns":1200000,"bytes_staged":1024}]}"#;
        let c = cases_of(loading);
        assert_eq!(c.len(), 2);
        assert_eq!(c["cold stage int8"].value, 1.2e6);
    }

    #[test]
    fn collects_direction_tagged_value_cases() {
        // The serving shape: latency quantiles (median_ns, default
        // lower-is-better) next to a higher-is-better throughput value.
        let serving = r#"{"bench":"serving","workloads":[
            {"name":"aggregate","shed":3,"cases":[
                {"name":"latency p999","median_ns":4800000},
                {"name":"throughput","value":350.5,"direction":"higher","unit":"req/s"}]}]}"#;
        let c = cases_of(serving);
        assert_eq!(c.len(), 2);
        let p999 = c["aggregate / latency p999"];
        assert!(p999.time_like && !p999.higher_is_better);
        let tp = c["aggregate / throughput"];
        assert_eq!(tp.value, 350.5);
        assert!(tp.higher_is_better && !tp.time_like);
        // An explicit "lower" direction parses as the default.
        let lower = cases_of(r#"[{"name":"x","value":5,"direction":"lower"}]"#);
        assert!(!lower["x"].higher_is_better);
    }

    #[test]
    fn median_ns_wins_when_both_metrics_present() {
        let c = cases_of(r#"[{"name":"x","median_ns":100,"value":9}]"#);
        assert_eq!(c["x"].value, 100.0);
        assert!(c["x"].time_like);
    }

    #[test]
    fn case_objects_do_not_recurse_into_themselves() {
        // A case with extra nested junk is still exactly one case.
        let doc = r#"[{"name":"x","median_ns":5,"extra":{"name":"inner","median_ns":9}}]"#;
        assert_eq!(cases_of(doc).len(), 1);
    }

    // Flag/positional splitting is covered where the helpers live
    // (`util::cli`); both gate binaries share them.

    #[test]
    fn regression_math_lower_is_better() {
        // 15% threshold: +14% passes, +16% fails (sanity on the formula
        // used in run(); kept in lockstep by construction).
        let base = 1_000_000.0f64;
        for (new, slow) in [(1_140_000.0, false), (1_160_000.0, true)] {
            let drift: f64 = new / base - 1.0;
            assert_eq!(drift > 0.15, slow);
        }
    }

    #[test]
    fn regression_math_higher_is_better() {
        // Throughput 1000 req/s baseline, 15% threshold: a drop to 860
        // passes, to 840 fails; any gain passes.
        let base = 1_000.0f64;
        for (new, drop) in [(860.0, false), (840.0, true), (1_500.0, false)] {
            let drift: f64 = 1.0 - new / base;
            assert_eq!(drift > 0.15, drop);
        }
    }
}
